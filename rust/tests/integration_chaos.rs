//! Fault-tolerance integration tests: the deterministic chaos harness
//! (`coordinator::chaos`) driving the supervised serving coordinator.
//! Everything here runs on mock engines with pinned seeds — no PJRT
//! artifacts — so the fault schedules are byte-identical on every run and
//! the assertions are exact:
//!
//! * every submitted request receives exactly one `Response` across
//!   {batch panic, batch error, slow batch, shard kill} × {1, 2, 4}
//!   shards, and traffic converges to 100% success once the schedule is
//!   exhausted;
//! * contained batch faults (panics / errors) never restart a shard;
//! * a shard kill forces a supervisor restart that re-warms the
//!   replacement engine from the preload artifact (task coverage proves
//!   the re-warm happened);
//! * expired requests are shed with `DeadlineExceeded`, not `Failed`;
//! * the circuit breaker opens after consecutive batch failures,
//!   fast-fails while open, and recovers through a half-open probe;
//! * injected preload / factory failures are absorbed (the shard keeps
//!   serving cold, or comes up after backoff), and a permanently dead
//!   shard answers every request with an error instead of hanging.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use mcnc::coordinator::{
    Batch, BatchPolicy, BreakerCfg, Chaos, ChaosCfg, EngineCore, FaultyEngine, RestartPolicy,
    ServeError, ServeStats, Server, ServerCfg, WarmStats,
};

/// Healthy inner engine the chaos wrapper injects faults around. With
/// `require_warm`, task coverage only exists after a `preload` — so a
/// restarted engine that still serves proves the supervisor re-warmed it.
struct ChaosMock {
    n_tasks: usize,
    require_warm: bool,
    warmed: bool,
    stats: ServeStats,
}

impl ChaosMock {
    fn new(n_tasks: usize) -> ChaosMock {
        ChaosMock { n_tasks, require_warm: false, warmed: false, stats: ServeStats::default() }
    }
}

impl EngineCore for ChaosMock {
    fn seq(&self) -> usize {
        8
    }

    fn has_task(&self, task: usize) -> bool {
        task < self.n_tasks && (!self.require_warm || self.warmed)
    }

    fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>> {
        self.stats.batches += 1;
        Ok(batch.requests.iter().map(|r| r.task as i32).collect())
    }

    fn stats_mut(&mut self) -> &mut ServeStats {
        &mut self.stats
    }

    fn into_stats(self) -> ServeStats {
        self.stats
    }

    fn preload(&mut self, _artifact: &Path) -> Result<WarmStats> {
        self.warmed = true;
        Ok(WarmStats { installed: self.n_tasks, ..WarmStats::default() })
    }
}

fn chaos_cfg(n_shards: usize, n_tasks: usize) -> ServerCfg {
    ServerCfg {
        n_tasks,
        n_shards,
        policy: BatchPolicy { max_batch: 4, max_delay: Duration::from_millis(1) },
        heartbeat: Duration::from_millis(10),
        ..ServerCfg::default()
    }
}

fn chaos_server(n_shards: usize, n_tasks: usize, chaos: &Chaos, require_warm: bool) -> Server {
    let cfg = chaos_cfg(n_shards, n_tasks);
    let c = chaos.clone();
    Server::start_with(&cfg, move |_shard| -> Result<FaultyEngine<ChaosMock>> {
        c.factory_gate()?;
        let mut inner = ChaosMock::new(n_tasks);
        inner.require_warm = require_warm;
        Ok(c.wrap(inner))
    })
    .expect("start chaos server")
}

fn recv(rx: std::sync::mpsc::Receiver<mcnc::coordinator::Response>) -> mcnc::coordinator::Response {
    rx.recv_timeout(Duration::from_secs(30)).expect("response")
}

#[test]
fn every_request_answered_exactly_once_under_faults() {
    // the acceptance matrix: {panic, error, slow, kill} × {1, 2, 4} shards
    for (n_shards, seed) in [(1usize, 101u64), (2, 202), (4, 404)] {
        let chaos = Chaos::new(ChaosCfg {
            seed,
            window: 12,
            panics: 2,
            errors: 2,
            slows: 1,
            slow_for: Duration::from_millis(2),
            kills: 1,
            ..ChaosCfg::default()
        });
        let n_tasks = 4;
        let server = chaos_server(n_shards, n_tasks, &chaos, false);
        for _wave in 0..200 {
            if chaos.exhausted() {
                break;
            }
            let rxs: Vec<_> = (0..n_tasks).map(|t| server.submit(t, vec![0; 8])).collect();
            for rx in rxs {
                let r = recv(rx);
                match &r.result {
                    Ok(tok) => assert_eq!(*tok, r.task as i32),
                    Err(ServeError::Failed(_)) => {}
                    Err(e) => panic!("unexpected outcome under faults: {e:?}"),
                }
                // exactly one Response per request, never a second
                assert!(rx.try_recv().is_err(), "second response for request {}", r.id);
            }
        }
        assert!(chaos.exhausted(), "{n_shards} shards: fault schedule never completed");
        let rep = chaos.report();
        assert_eq!((rep.panics, rep.errors, rep.slows, rep.kills), (2, 2, 1, 1));
        // post-schedule: traffic converges back to 100% success
        let rxs: Vec<_> = (0..n_tasks).map(|t| server.submit(t, vec![0; 8])).collect();
        for rx in rxs {
            let r = recv(rx);
            assert!(r.is_ok(), "{n_shards} shards, post-schedule failure: {:?}", r.result);
        }
        let stats = server.stop().expect("no shard may die permanently");
        assert_eq!(stats.restarts, 1, "{n_shards} shards: the kill forces exactly one restart");
    }
}

#[test]
fn batch_panics_and_errors_are_contained_without_restarts() {
    let chaos =
        Chaos::new(ChaosCfg { seed: 9, window: 12, panics: 3, errors: 3, ..ChaosCfg::default() });
    let n_tasks = 4;
    let server = chaos_server(2, n_tasks, &chaos, false);
    let mut failed = 0usize;
    for _wave in 0..200 {
        if chaos.exhausted() {
            break;
        }
        let rxs: Vec<_> = (0..n_tasks).map(|t| server.submit(t, vec![0; 8])).collect();
        for rx in rxs {
            let r = recv(rx);
            match &r.result {
                Ok(_) => {}
                Err(ServeError::Failed(m)) => {
                    failed += 1;
                    assert!(m.contains("chaos: injected batch"), "{m}");
                }
                Err(e) => panic!("unexpected outcome: {e:?}"),
            }
        }
    }
    assert!(chaos.exhausted());
    let rep = chaos.report();
    assert_eq!((rep.panics, rep.errors), (3, 3));
    // one request per batch here, so each faulted batch fails exactly one
    assert_eq!(failed, 6, "each injected fault answers its batch with Failed");
    let stats = server.stop().unwrap();
    assert_eq!(stats.restarts, 0, "contained batch faults never restart a shard");
    assert_eq!(stats.batch_panics, 3, "every contained panic is counted");
    assert_eq!(stats.errors, 6);
}

#[test]
fn restart_rewarms_replacement_engine_from_preload_artifact() {
    let chaos = Chaos::new(ChaosCfg { seed: 5, window: 6, kills: 1, ..ChaosCfg::default() });
    let n_tasks = 2;
    let server = chaos_server(1, n_tasks, &chaos, true);
    // before the preload the engine serves nothing: coverage is warm-only
    let r = recv(server.submit(0, vec![0; 8]));
    assert!(matches!(r.result, Err(ServeError::Failed(_))), "{:?}", r.result);
    let warm = server.preload(Path::new("chaos-warm.mcnc2")).unwrap();
    assert_eq!(warm.installed, n_tasks);
    // drive traffic until the scheduled kill fires and the shard restarts
    for _wave in 0..200 {
        if chaos.exhausted() {
            break;
        }
        let rxs: Vec<_> = (0..n_tasks).map(|t| server.submit(t, vec![0; 8])).collect();
        for rx in rxs {
            let r = recv(rx);
            assert!(
                r.is_ok() || matches!(r.result, Err(ServeError::Failed(_))),
                "{:?}",
                r.result
            );
        }
    }
    assert!(chaos.exhausted());
    assert_eq!(chaos.report().kills, 1);
    // the replacement engine re-warmed itself from the parked artifact:
    // it still has task coverage, so traffic succeeds instead of failing
    // with "unknown task" from a cold rebuild
    let r = recv(server.submit(1, vec![0; 8]));
    assert!(r.is_ok(), "restarted shard lost its warm coverage: {:?}", r.result);
    let stats = server.stop().unwrap();
    assert_eq!(stats.restarts, 1);
}

#[test]
fn expired_requests_shed_with_deadline_exceeded_not_failed() {
    let cfg = ServerCfg {
        // a zero deadline expires at submission: every request must be
        // shed at batch formation, deterministically
        deadline: Some(Duration::ZERO),
        ..chaos_cfg(1, 2)
    };
    let server = Server::start_with(&cfg, |_| -> Result<ChaosMock> { Ok(ChaosMock::new(2)) })
        .expect("start");
    for i in 0..8 {
        let r = recv(server.submit(i % 2, vec![0; 8]));
        assert_eq!(r.result, Err(ServeError::DeadlineExceeded), "request {i}");
    }
    // per-request override: no deadline → served normally
    let r = recv(server.submit_with(0, vec![0; 8], None));
    assert!(r.is_ok(), "{:?}", r.result);
    let stats = server.stop().unwrap();
    assert_eq!(stats.deadline_shed, 8);
    assert_eq!(stats.errors, 0, "shedding is not an execution error");
    assert_eq!(stats.latency.count(), 1, "only the deadline-free request completed");
}

/// Engine whose batches fail until `healthy` flips — drives the breaker
/// through open → half-open → closed deterministically.
struct FlakyMock {
    healthy: Arc<AtomicBool>,
    stats: ServeStats,
}

impl EngineCore for FlakyMock {
    fn seq(&self) -> usize {
        8
    }

    fn has_task(&self, task: usize) -> bool {
        task < 4
    }

    fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>> {
        if !self.healthy.load(Ordering::SeqCst) {
            anyhow::bail!("induced batch failure");
        }
        self.stats.batches += 1;
        Ok(batch.requests.iter().map(|_| 0).collect())
    }

    fn stats_mut(&mut self) -> &mut ServeStats {
        &mut self.stats
    }

    fn into_stats(self) -> ServeStats {
        self.stats
    }
}

#[test]
fn breaker_opens_fast_fails_and_recovers_via_probe() {
    let healthy = Arc::new(AtomicBool::new(false));
    let cfg = ServerCfg {
        policy: BatchPolicy { max_batch: 1, max_delay: Duration::ZERO },
        breaker: BreakerCfg { threshold: 3, cooldown: Duration::from_millis(20) },
        ..chaos_cfg(1, 4)
    };
    let h = Arc::clone(&healthy);
    let server = Server::start_with(&cfg, move |_| -> Result<FlakyMock> {
        Ok(FlakyMock { healthy: Arc::clone(&h), stats: ServeStats::default() })
    })
    .expect("start");
    // three consecutive batch failures open the breaker (the breaker is
    // updated before the batch's responses are sent, so sequential
    // submit/recv pairs observe it deterministically)
    for _ in 0..3 {
        let r = recv(server.submit(0, vec![0; 8]));
        assert!(matches!(r.result, Err(ServeError::Failed(_))), "{:?}", r.result);
    }
    // open: the dispatcher fast-fails before the admission queue
    let r = recv(server.submit(0, vec![0; 8]));
    match &r.result {
        Err(ServeError::Rejected(m)) => assert!(m.contains("circuit open"), "{m}"),
        other => panic!("expected a circuit-open rejection, got {other:?}"),
    }
    // heal the engine and wait out the cooldown: exactly one probe is
    // admitted (half-open), succeeds, and closes the breaker
    healthy.store(true, Ordering::SeqCst);
    std::thread::sleep(Duration::from_millis(30));
    let r = recv(server.submit(0, vec![0; 8]));
    assert!(r.is_ok(), "probe should close the breaker: {:?}", r.result);
    let r = recv(server.submit(0, vec![0; 8]));
    assert!(r.is_ok(), "closed breaker serves normally: {:?}", r.result);
    let stats = server.stop().unwrap();
    assert_eq!(stats.breaker_opens, 1);
    assert!(stats.breaker_fastfail >= 1);
    assert_eq!(stats.restarts, 0, "the breaker absorbs failures without restarts");
}

#[test]
fn injected_preload_failure_leaves_the_shard_serving() {
    let chaos = Chaos::new(ChaosCfg { seed: 3, preload_fails: 1, ..ChaosCfg::default() });
    let server = chaos_server(1, 2, &chaos, false);
    let err = server.preload(Path::new("warm.mcnc2")).unwrap_err();
    assert!(format!("{err:#}").contains("injected preload failure"), "{err:#}");
    let r = recv(server.submit(0, vec![0; 8]));
    assert!(r.is_ok(), "a failed preload must not take the shard down: {:?}", r.result);
    // the failure budget is spent: a retry goes through
    server.preload(Path::new("warm.mcnc2")).unwrap();
    assert_eq!(chaos.report().preload_fails, 1);
    server.stop().unwrap();
}

#[test]
fn factory_failure_is_absorbed_by_restart_backoff() {
    let chaos = Chaos::new(ChaosCfg { seed: 2, factory_fails: 1, ..ChaosCfg::default() });
    let server = chaos_server(1, 2, &chaos, false);
    let r = recv(server.submit(0, vec![0; 8]));
    assert!(r.is_ok(), "shard must come up after the factory failure: {:?}", r.result);
    let stats = server.stop().unwrap();
    assert_eq!(stats.restarts, 1);
    assert_eq!(chaos.report().factory_fails, 1);
}

#[test]
fn permanently_dead_shard_answers_instead_of_hanging() {
    // more factory failures than the restart budget: the shard dies for
    // good, and every queued or late request must still get a Response
    let chaos = Chaos::new(ChaosCfg { seed: 1, factory_fails: 8, ..ChaosCfg::default() });
    let cfg = ServerCfg {
        restart: RestartPolicy {
            max_restarts: 1,
            backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(4),
        },
        ..chaos_cfg(1, 2)
    };
    let c = chaos.clone();
    let server = Server::start_with(&cfg, move |_| -> Result<FaultyEngine<ChaosMock>> {
        c.factory_gate()?;
        Ok(c.wrap(ChaosMock::new(2)))
    })
    .expect("start");
    let rxs: Vec<_> = (0..6).map(|i| server.submit(i % 2, vec![0; 8])).collect();
    for rx in rxs {
        let r = recv(rx);
        match &r.result {
            Err(ServeError::Failed(m)) => assert!(m.contains("dead"), "{m}"),
            other => panic!("dead shard must answer Failed, got {other:?}"),
        }
    }
    let err = server.stop().unwrap_err();
    assert!(err.to_string().contains("permanently dead"), "{err:#}");
}
