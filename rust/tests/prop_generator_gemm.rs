//! Randomized bit-exactness properties for the blocked-GEMM reconstruction
//! engine (`mcnc::kernel`): the batched `Generator::forward` must agree
//! bit-for-bit with the retained per-chunk matvec reference
//! (`forward_naive`) across the whole config space, and the NOLA
//! reconstruction must agree with a naive triple loop. This is the
//! contract that lets the serving engine swap kernels without revalidating
//! any downstream numerics.

use mcnc::baselines::nola::{reconstruct_deltas, TargetDims};
use mcnc::mcnc::{Act, GenCfg, Generator};
use mcnc::prop_assert;
use mcnc::util::prop::run_prop;

const ACTS: [Act; 6] =
    [Act::Sine, Act::Sigmoid, Act::Relu, Act::LeakyRelu, Act::Elu, Act::Linear];

#[test]
fn blocked_gemm_forward_bit_identical_to_naive() {
    run_prop("gemm_vs_naive_forward", 60, |g| {
        let cfg = GenCfg {
            k: g.usize(1, 16),
            d: g.usize(1, 200),
            width: g.usize(2, 48),
            depth: g.usize(2, 4),
            act: *g.pick(&ACTS),
            residual: g.bool(),
            normalize: g.bool(),
            freq: g.f32(0.5, 6.0),
            ..GenCfg::default()
        };
        let n = g.usize(1, 33); // crosses the MR=4 tile edges
        let seed = g.usize(0, 1 << 20) as u64;
        let gen = Generator::from_seed(cfg.clone(), seed);
        let alpha = g.vec_f32(n * cfg.k, -2.0, 2.0);
        let beta = g.vec_f32(n, -1.5, 1.5);

        let fast = gen.forward(&alpha, &beta);
        let mut slow = vec![0.0f32; n * cfg.d];
        gen.forward_naive(&alpha, &beta, &mut slow);
        for (i, (a, b)) in fast.iter().zip(&slow).enumerate() {
            prop_assert!(
                a.to_bits() == b.to_bits(),
                "cfg {cfg:?} n={n} out[{i}]: gemm {a:e} vs naive {b:e}"
            );
        }
        Ok(())
    });
}

#[test]
fn reconstruct_delta_is_a_forward_prefix() {
    run_prop("reconstruct_prefix", 40, |g| {
        let cfg = GenCfg {
            k: g.usize(1, 8),
            d: g.usize(1, 64),
            width: g.usize(2, 16),
            depth: 3,
            ..GenCfg::default()
        };
        let n = g.usize(1, 9);
        let dc = g.usize(1, n * cfg.d);
        let gen = Generator::from_seed(cfg.clone(), 7);
        let alpha = g.vec_f32(n * cfg.k, -1.0, 1.0);
        let beta = g.vec_f32(n, -1.0, 1.0);
        let full = gen.forward(&alpha, &beta);
        let delta = gen.reconstruct_delta(&alpha, &beta, dc);
        prop_assert!(delta.len() == dc, "len {} != dc {dc}", delta.len());
        for (i, (a, b)) in delta.iter().zip(&full).enumerate() {
            prop_assert!(a.to_bits() == b.to_bits(), "delta[{i}] {a} vs {b}");
        }
        Ok(())
    });
}

#[test]
fn nola_gemm_matches_naive_triple_loop() {
    run_prop("nola_gemm_vs_naive", 40, |g| {
        let n_targets = g.usize(1, 3);
        let rank = g.usize(1, 6);
        let m = g.usize(1, 5);
        let dims: Vec<TargetDims> = (0..n_targets)
            .map(|_| TargetDims { a: g.usize(1, 12), b: g.usize(1, 19) })
            .collect();
        let na: usize = dims.iter().map(|t| t.a * rank).sum();
        let nb: usize = dims.iter().map(|t| rank * t.b).sum();
        let coef_a = g.vec_f32(n_targets * m, -1.0, 1.0);
        let coef_b = g.vec_f32(n_targets * m, -1.0, 1.0);
        let basis_a = g.vec_f32(m * na, -1.0, 1.0);
        let basis_b = g.vec_f32(m * nb, -1.0, 1.0);

        let got = reconstruct_deltas(&dims, rank, &coef_a, &coef_b, &basis_a, &basis_b, m);

        // naive reference: ascending-index accumulation everywhere
        let (mut ao, mut bo) = (0usize, 0usize);
        for (l, t) in dims.iter().enumerate() {
            let alen = t.a * rank;
            let blen = rank * t.b;
            let mut fa = vec![0.0f32; alen];
            let mut fb = vec![0.0f32; blen];
            for j in 0..m {
                let ca = coef_a[l * m + j];
                let cb = coef_b[l * m + j];
                for (x, &v) in fa.iter_mut().zip(&basis_a[m * ao + j * alen..]) {
                    *x += ca * v;
                }
                for (x, &v) in fb.iter_mut().zip(&basis_b[m * bo + j * blen..]) {
                    *x += cb * v;
                }
            }
            let mut dw = vec![0.0f32; t.a * t.b];
            for i in 0..t.a {
                for r in 0..rank {
                    let av = fa[i * rank + r];
                    for j in 0..t.b {
                        dw[i * t.b + j] += av * fb[r * t.b + j];
                    }
                }
            }
            for (i, (a, b)) in got[l].iter().zip(&dw).enumerate() {
                prop_assert!(
                    a.to_bits() == b.to_bits(),
                    "target {l} dw[{i}]: {a} vs {b}"
                );
            }
            ao += alen;
            bo += blen;
        }
        Ok(())
    });
}
