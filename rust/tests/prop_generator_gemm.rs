//! Randomized parity properties for the reconstruction microkernel layer
//! (`mcnc::kernel`) and the engines on top of it.
//!
//! Two contracts are pinned:
//!
//! * **Bit-exactness of the scalar path.** A forced-scalar kernel
//!   (`pack_b_for(Isa::Scalar, …)` / `gemv_for` — the dispatch override
//!   hook) must agree bit-for-bit with the naive ascending-K reference,
//!   exactly as in PR 1. This runs on every host, so CI on a scalar-only
//!   box still exercises the dispatch seam.
//! * **SIMD-vs-scalar parity.** The dispatched kernel (AVX2+FMA or NEON
//!   when available) keeps the same ascending-K reduction order but fuses
//!   each multiply-add, so it must match the scalar path within a tight
//!   magnitude-scaled ulp bound: `|Δ| ≤ 2(K+1)·ε·Σ|a·b|` per element,
//!   with NaN/inf classification identical. Remainder tiles for every
//!   microtile in the tree (MR ∈ {4,6,8}, NR ∈ {8,16}) are swept
//!   exhaustively, and denormal/NaN/±inf inputs are injected explicitly.
//!
//! This is what lets the serving engine swap kernels per host without
//! revalidating any downstream numerics.

use mcnc::baselines::nola::{reconstruct_deltas, TargetDims};
use mcnc::codec::quantizer;
use mcnc::mcnc::kernel::{self, Isa};
use mcnc::mcnc::{Act, GenCfg, Generator};
use mcnc::prop_assert;
use mcnc::util::prng::Stream;
use mcnc::util::prop::run_prop;

const ACTS: [Act; 6] =
    [Act::Sine, Act::Sigmoid, Act::Relu, Act::LeakyRelu, Act::Elu, Act::Linear];

/// Ascending-K reference product (the contract every path honors).
fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            for j in 0..n {
                c[i * n + j] += av * b[p * n + j];
            }
        }
    }
    c
}

/// Fused-vs-unfused closeness for one output element: the difference is
/// bounded by `2(K+1)·ε` ulps of the term-magnitude sum, plus denormal
/// slop; NaN/inf classification must agree exactly.
fn check_close(got: f32, want: f32, mag: f64, k: usize, ctx: &str) -> Result<(), String> {
    if want.is_nan() {
        return if got.is_nan() { Ok(()) } else { Err(format!("{ctx}: {got} vs NaN")) };
    }
    if want.is_infinite() {
        return if got == want { Ok(()) } else { Err(format!("{ctx}: {got} vs {want}")) };
    }
    let tol = 2.0 * (k + 1) as f64 * f32::EPSILON as f64 * mag + 2.0 * f32::MIN_POSITIVE as f64;
    let diff = (got as f64 - want as f64).abs();
    if diff <= tol {
        Ok(())
    } else {
        Err(format!("{ctx}: {got} vs {want} (diff {diff:e} > tol {tol:e})"))
    }
}

fn term_mag(a: &[f32], b: &[f32], i: usize, j: usize, k: usize, n: usize) -> f64 {
    (0..k).map(|p| (a[i * k + p] as f64 * b[p * n + j] as f64).abs()).sum()
}

#[test]
fn forced_scalar_gemm_bit_identical_to_naive() {
    run_prop("forced_scalar_vs_naive", 50, |g| {
        let m = g.usize(1, 20);
        let k = g.usize(1, 70);
        let n = g.usize(1, 40);
        let a = g.vec_f32(m * k, -2.0, 2.0);
        let b = g.vec_f32(k * n, -1.0, 1.0);
        let pb = kernel::pack_b_for(Isa::Scalar, &b, k, n);
        prop_assert!(pb.isa() == Isa::Scalar, "override hook must pin scalar, got {:?}", pb.isa());
        let mut c = vec![f32::NAN; m * n];
        kernel::gemm(&a, m, &pb, &mut c);
        let want = naive(&a, &b, m, k, n);
        for (i, (x, w)) in c.iter().zip(&want).enumerate() {
            prop_assert!(
                x.to_bits() == w.to_bits(),
                "({m},{k},{n})[{i}]: scalar {x:e} vs naive {w:e}"
            );
        }
        Ok(())
    });
}

#[test]
fn dispatched_gemm_agrees_with_forced_scalar() {
    let scalar_active = kernel::active() == Isa::Scalar;
    run_prop("dispatched_vs_forced_scalar", 50, |g| {
        let m = g.usize(1, 20);
        let k = g.usize(1, 70);
        let n = g.usize(1, 40);
        let a = g.vec_f32(m * k, -2.0, 2.0);
        let b = g.vec_f32(k * n, -1.0, 1.0);
        let ps = kernel::pack_b_for(Isa::Scalar, &b, k, n);
        let pd = kernel::pack_b(&b, k, n);
        prop_assert!(kernel::available(pd.isa()), "dispatched to unavailable {:?}", pd.isa());
        let mut cs = vec![f32::NAN; m * n];
        let mut cd = vec![f32::NAN; m * n];
        kernel::gemm(&a, m, &ps, &mut cs);
        kernel::gemm(&a, m, &pd, &mut cd);
        for i in 0..m {
            for j in 0..n {
                let (got, want) = (cd[i * n + j], cs[i * n + j]);
                if scalar_active {
                    prop_assert!(
                        got.to_bits() == want.to_bits(),
                        "({m},{k},{n})[{i},{j}]: {got:e} vs {want:e}"
                    );
                } else {
                    let mag = term_mag(&a, &b, i, j, k, n);
                    check_close(got, want, mag, k, &format!("({m},{k},{n})[{i},{j}]"))?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn simd_parity_covers_every_remainder_tile_shape() {
    // exhaustive m residues for MR ∈ {4, 6, 8} and n residues for NR ∈
    // {8, 16}: m ∈ 1..=13 hits every m % MR, n ∈ 1..=17 ∪ {31, 32, 33}
    // hits every n % NR including full-tile and one-past boundaries.
    let mut shapes = Vec::new();
    for m in 1..=13usize {
        for n in (1..=17usize).chain([31, 32, 33]) {
            shapes.push((m, n));
        }
    }
    for &(m, n) in &shapes {
        for k in [1usize, 7, 33] {
            let a = Stream::new((m * 41 + n * 7 + k) as u64).uniform_f32(m * k, -2.0, 2.0);
            let b = Stream::new((m + n * 13 + k * 3) as u64).uniform_f32(k * n, -1.0, 1.0);
            let ps = kernel::pack_b_for(Isa::Scalar, &b, k, n);
            let pd = kernel::pack_b(&b, k, n);
            let mut cs = vec![f32::NAN; m * n];
            let mut cd = vec![f32::NAN; m * n];
            kernel::gemm(&a, m, &ps, &mut cs);
            kernel::gemm(&a, m, &pd, &mut cd);
            for i in 0..m {
                for j in 0..n {
                    let mag = term_mag(&a, &b, i, j, k, n);
                    let ctx = format!("({m},{k},{n})[{i},{j}]");
                    if let Err(e) = check_close(cd[i * n + j], cs[i * n + j], mag, k, &ctx) {
                        panic!("{e}");
                    }
                }
            }
        }
    }
}

#[test]
fn simd_parity_with_denormal_nan_and_inf_inputs() {
    run_prop("simd_parity_nonfinite", 40, |g| {
        let m = g.usize(1, 10);
        let k = g.usize(1, 24);
        let n = g.usize(1, 34);
        let mut a = g.vec_f32(m * k, -2.0, 2.0);
        let mut b = g.vec_f32(k * n, -1.0, 1.0);
        // inject specials: denormals always, NaN/±inf in A only (so a
        // whole C row goes non-finite and stays position-comparable)
        let ai = g.usize(0, a.len() - 1);
        a[ai] = 1.0e-42 * a[ai];
        let bi = g.usize(0, b.len() - 1);
        b[bi] = 7.0e-43;
        if g.bool() {
            a[g.usize(0, a.len() - 1)] = f32::NAN;
        }
        if g.bool() {
            a[g.usize(0, a.len() - 1)] = f32::INFINITY;
        }
        let ps = kernel::pack_b_for(Isa::Scalar, &b, k, n);
        let pd = kernel::pack_b(&b, k, n);
        let mut cs = vec![f32::NAN; m * n];
        let mut cd = vec![f32::NAN; m * n];
        kernel::gemm(&a, m, &ps, &mut cs);
        kernel::gemm(&a, m, &pd, &mut cd);
        for i in 0..m {
            for j in 0..n {
                let mag = term_mag(&a, &b, i, j, k, n);
                let ctx = format!("({m},{k},{n})[{i},{j}]");
                check_close(cd[i * n + j], cs[i * n + j], mag, k, &ctx)?;
            }
        }
        Ok(())
    });
}

#[test]
fn gemv_forced_scalar_exact_and_dispatched_close() {
    let scalar_active = kernel::active() == Isa::Scalar;
    run_prop("gemv_parity", 50, |g| {
        let k = g.usize(1, 40);
        // cover the 32/8 (AVX2) and 16/4 (NEON) column-block tails
        let n = if g.bool() { g.usize(1, 40) } else { g.usize(60, 70) };
        let x = g.vec_f32(k, -2.0, 2.0);
        let b = g.vec_f32(k * n, -1.0, 1.0);
        let want = naive(&x, &b, 1, k, n);
        let mut fs = vec![f32::NAN; n];
        kernel::gemv_for(Isa::Scalar, &x, &b, k, n, &mut fs);
        for (j, (a, w)) in fs.iter().zip(&want).enumerate() {
            prop_assert!(a.to_bits() == w.to_bits(), "scalar gemv [{j}]: {a:e} vs {w:e}");
        }
        let mut fd = vec![f32::NAN; n];
        kernel::gemv(&x, &b, k, n, &mut fd);
        for j in 0..n {
            if scalar_active {
                prop_assert!(fd[j].to_bits() == fs[j].to_bits(), "gemv [{j}]");
            } else {
                let mag = term_mag(&x, &b, 0, j, k, n);
                check_close(fd[j], fs[j], mag, k, &format!("gemv [{j}] (k={k} n={n})"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn quantizer_scans_are_isa_invariant() {
    run_prop("quantize_isa_invariant", 40, |g| {
        let len = g.usize(1, 600);
        let mut w = g.vec_f32(len, -4.0, 4.0);
        // sprinkle exact ties, denormals and non-finites — the wire bytes
        // must still not depend on the encoding host
        for _ in 0..g.usize(0, 6) {
            let i = g.usize(0, len - 1);
            w[i] = match g.usize(0, 4) {
                0 => (g.usize(0, 7) as f32 + 0.5) * if g.bool() { 1.0 } else { -1.0 },
                1 => 1.0e-42,
                2 => f32::NAN,
                3 => f32::INFINITY,
                _ => 0.0,
            };
        }
        let bits = [2u32, 4, 8][g.usize(0, 2)];
        let block = g.usize(1, 96);
        let scalar = quantizer::quantize_with(Isa::Scalar, &w, bits, block);
        let active = quantizer::quantize_with(kernel::active(), &w, bits, block);
        prop_assert!(
            scalar == active,
            "bits={bits} block={block}: ISA-dependent encoding (len {len})"
        );
        Ok(())
    });
}

#[test]
fn blocked_gemm_forward_matches_naive() {
    // bit-identical when the scalar kernel is active (the PR-1 contract);
    // row-magnitude-bounded under a SIMD kernel, whose fused terms
    // propagate last-ulp noise through the depth-bounded layer stack.
    let scalar_active = kernel::active() == Isa::Scalar;
    run_prop("gemm_vs_naive_forward", 60, |g| {
        let cfg = GenCfg {
            k: g.usize(1, 16),
            d: g.usize(1, 200),
            width: g.usize(2, 48),
            depth: g.usize(2, 4),
            act: *g.pick(&ACTS),
            residual: g.bool(),
            normalize: g.bool(),
            freq: g.f32(0.5, 6.0),
            ..GenCfg::default()
        };
        let n = g.usize(1, 33); // crosses every MR tile edge
        let seed = g.usize(0, 1 << 20) as u64;
        let gen = Generator::from_seed(cfg.clone(), seed);
        let alpha = g.vec_f32(n * cfg.k, -2.0, 2.0);
        let beta = g.vec_f32(n, -1.5, 1.5);

        let fast = gen.forward(&alpha, &beta);
        let mut slow = vec![0.0f32; n * cfg.d];
        gen.forward_naive(&alpha, &beta, &mut slow);
        for (r, (frow, srow)) in fast.chunks(cfg.d).zip(slow.chunks(cfg.d)).enumerate() {
            let row_max = srow.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            for (i, (a, b)) in frow.iter().zip(srow).enumerate() {
                let ok = if scalar_active {
                    a.to_bits() == b.to_bits()
                } else {
                    (a - b).abs() <= 2.5e-3 * (1.0 + row_max)
                };
                prop_assert!(
                    ok,
                    "cfg {cfg:?} n={n} row {r} [{i}]: gemm {a:e} vs naive {b:e}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn reconstruct_delta_is_a_forward_prefix() {
    run_prop("reconstruct_prefix", 40, |g| {
        let cfg = GenCfg {
            k: g.usize(1, 8),
            d: g.usize(1, 64),
            width: g.usize(2, 16),
            depth: 3,
            ..GenCfg::default()
        };
        let n = g.usize(1, 9);
        let dc = g.usize(1, n * cfg.d);
        let gen = Generator::from_seed(cfg.clone(), 7);
        let alpha = g.vec_f32(n * cfg.k, -1.0, 1.0);
        let beta = g.vec_f32(n, -1.0, 1.0);
        let full = gen.forward(&alpha, &beta);
        let delta = gen.reconstruct_delta(&alpha, &beta, dc);
        prop_assert!(delta.len() == dc, "len {} != dc {dc}", delta.len());
        for (i, (a, b)) in delta.iter().zip(&full).enumerate() {
            prop_assert!(a.to_bits() == b.to_bits(), "delta[{i}] {a} vs {b}");
        }
        Ok(())
    });
}

#[test]
fn nola_gemm_matches_naive_triple_loop() {
    let scalar_active = kernel::active() == Isa::Scalar;
    run_prop("nola_gemm_vs_naive", 40, |g| {
        let n_targets = g.usize(1, 3);
        let rank = g.usize(1, 6);
        let m = g.usize(1, 5);
        let dims: Vec<TargetDims> = (0..n_targets)
            .map(|_| TargetDims { a: g.usize(1, 12), b: g.usize(1, 19) })
            .collect();
        let na: usize = dims.iter().map(|t| t.a * rank).sum();
        let nb: usize = dims.iter().map(|t| rank * t.b).sum();
        let coef_a = g.vec_f32(n_targets * m, -1.0, 1.0);
        let coef_b = g.vec_f32(n_targets * m, -1.0, 1.0);
        let basis_a = g.vec_f32(m * na, -1.0, 1.0);
        let basis_b = g.vec_f32(m * nb, -1.0, 1.0);

        let got = reconstruct_deltas(&dims, rank, &coef_a, &coef_b, &basis_a, &basis_b, m);

        // naive reference: ascending-index accumulation everywhere
        let (mut ao, mut bo) = (0usize, 0usize);
        for (l, t) in dims.iter().enumerate() {
            let alen = t.a * rank;
            let blen = rank * t.b;
            let mut fa = vec![0.0f32; alen];
            let mut fb = vec![0.0f32; blen];
            for j in 0..m {
                let ca = coef_a[l * m + j];
                let cb = coef_b[l * m + j];
                for (x, &v) in fa.iter_mut().zip(&basis_a[m * ao + j * alen..]) {
                    *x += ca * v;
                }
                for (x, &v) in fb.iter_mut().zip(&basis_b[m * bo + j * blen..]) {
                    *x += cb * v;
                }
            }
            let mut dw = vec![0.0f32; t.a * t.b];
            for i in 0..t.a {
                for r in 0..rank {
                    let av = fa[i * rank + r];
                    for j in 0..t.b {
                        dw[i * t.b + j] += av * fb[r * t.b + j];
                    }
                }
            }
            for (i, (a, b)) in got[l].iter().zip(&dw).enumerate() {
                let ok = if scalar_active {
                    a.to_bits() == b.to_bits()
                } else {
                    // two fused stages (combine + A·B) over ≤ m+rank terms
                    // of [-1,1] inputs: 2e-3 absolute+relative is ~10x the
                    // worst accumulated fused-vs-unfused drift
                    (a - b).abs() <= 2e-3 * (1.0 + b.abs())
                };
                prop_assert!(ok, "target {l} dw[{i}]: {a:e} vs {b:e}");
            }
            ao += alen;
            bo += blen;
        }
        Ok(())
    });
}
