//! End-to-end compressed-domain serving over the MCNP1 socket front-end:
//! a fleet of per-task head matrices is packed at int8, served by
//! `QuantEngine` (rANS → quantized panels → int8 GEMM, no f32 weights),
//! and every prediction must be *identical* to the forced-f32 oracle
//! server fed the same artifact and the same requests — including after
//! `Chaos` kills a shard and the supervisor re-warms the replacement from
//! the parked artifact.
//!
//! The fixture weights are engineered so int8 error cannot flip an
//! argmax: task `t`'s target column carries weight 8.0, every other
//! column ≤ 0.25, and requests use token values ≤ 4 — the target/runner-up
//! logit gap is orders of magnitude above the quantization error bound
//! pinned by `prop_int8_gemm.rs`, so "identical predictions" is a sound
//! requirement, not a lucky one.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use anyhow::Result;
use mcnc::codec::Codec;
use mcnc::coordinator::{
    warm, BatchPolicy, Chaos, ChaosCfg, QServeCfg, QuantEngine, Server, ServerCfg, WEIGHT_SLOT,
};
use mcnc::net::protocol::{encode_frame, Deframer, Msg, NET_MAGIC};
use mcnc::net::{NetCfg, NetListener, NetReport};
use mcnc::tensor::Tensor;

const SEQ: usize = 8;
const VOCAB: usize = 16;
const N_TASKS: usize = 6;
const N_SHARDS: usize = 2;

/// Write the engineered int8 warm artifact (see module docs) to a temp
/// file and return its path.
fn fixture_artifact(tag: &str) -> PathBuf {
    let mut adapters = Vec::new();
    for t in 0..N_TASKS {
        let target = t % VOCAB;
        let mut w = vec![0.0f32; SEQ * VOCAB];
        for kk in 0..SEQ {
            for j in 0..VOCAB {
                let h = ((kk * 31 + j * 17 + t * 7) % 101) as f32 / 100.0 - 0.5;
                w[kk * VOCAB + j] = if j == target { 8.0 } else { h * 0.5 };
            }
        }
        let tensor = Tensor::from_f32(w, &[SEQ, VOCAB]).expect("fixture tensor");
        adapters.push((t, vec![(WEIGHT_SLOT.to_string(), tensor)]));
    }
    let mut bytes = Vec::new();
    warm::write_artifact(&mut bytes, "panelhead", 11, Codec::Int8 { block: VOCAB }, &adapters)
        .expect("write warm artifact");
    let dir = std::env::temp_dir().join("mcnc_quant_serving");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join(format!("{tag}_{}.mcnc2", std::process::id()));
    std::fs::write(&path, &bytes).expect("write artifact file");
    path
}

fn qserve_cfg(artifact: PathBuf, force_f32: bool) -> QServeCfg {
    QServeCfg {
        kind: "panelhead".to_string(),
        n_tasks: N_TASKS,
        n_shards: N_SHARDS,
        seq: SEQ,
        vocab: VOCAB,
        force_f32,
        artifact: Some(artifact),
    }
}

fn server_cfg() -> ServerCfg {
    ServerCfg {
        n_tasks: N_TASKS,
        n_shards: N_SHARDS,
        policy: BatchPolicy { max_batch: 4, max_delay: Duration::from_millis(1) },
        heartbeat: Duration::from_millis(10),
        ..ServerCfg::default()
    }
}

/// A server of `QuantEngine`s over the given artifact.
fn quant_server(artifact: &PathBuf, force_f32: bool) -> Server {
    let cfg = qserve_cfg(artifact.clone(), force_f32);
    Server::start_with(&server_cfg(), move |shard| -> Result<QuantEngine> {
        QuantEngine::new(cfg.clone(), shard)
    })
    .expect("start quant server")
}

/// Bind an ephemeral loopback listener, run its poll loop while `f`
/// drives clients, then stop and hand back `f`'s result and the report.
fn with_listener<R>(server: &Server, f: impl FnOnce(SocketAddr) -> R) -> (R, NetReport) {
    let listener = NetListener::bind(NetCfg::default()).expect("bind loopback");
    let addr = listener.local_addr().expect("local addr");
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let pump = scope.spawn(|| listener.run(server, &stop));
        let r = f(addr);
        stop.store(true, Ordering::Relaxed);
        let report = pump.join().expect("listener thread").expect("listener run");
        (r, report)
    })
}

/// Minimal blocking MCNP1 client (mirrors `integration_net.rs`).
struct Client {
    stream: TcpStream,
    de: Deframer,
    buf: Vec<u8>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs(30))).expect("read timeout");
        let mut c = Client { stream, de: Deframer::new(), buf: vec![0u8; 16 * 1024] };
        c.stream.write_all(NET_MAGIC).expect("preamble");
        c
    }

    fn send(&mut self, m: &Msg) {
        self.stream.write_all(&encode_frame(m)).expect("send frame");
    }

    fn recv(&mut self) -> Msg {
        loop {
            if let Some(m) = self.de.next().expect("deframe reply") {
                return m;
            }
            let n = self.stream.read(&mut self.buf).expect("read reply");
            assert!(n > 0, "connection closed while awaiting a reply");
            self.de.push(&self.buf[..n]);
        }
    }
}

/// Deterministic small-valued token pattern for (task, round): values ≤ 4.
fn probe_tokens(task: usize, round: usize) -> Vec<i32> {
    (0..SEQ).map(|j| ((j + round * 3 + task) % 5) as i32).collect()
}

fn request(id: u64, task: usize, tokens: Vec<i32>) -> Msg {
    Msg::Req { id, task: task as u64, tokens, deadline_us: 0 }
}

/// Send one request and return the prediction from a `ReplyOk`.
fn ask(c: &mut Client, id: u64, task: usize, tokens: Vec<i32>) -> i32 {
    c.send(&request(id, task, tokens));
    match c.recv() {
        Msg::ReplyOk { id: rid, token, .. } => {
            assert_eq!(rid, id, "reply id mismatch");
            token
        }
        other => panic!("task {task} req {id}: unexpected {other:?}"),
    }
}

#[test]
fn quantized_serving_matches_f32_oracle_on_every_socket_prediction() {
    let artifact = fixture_artifact("parity");
    let qs = quant_server(&artifact, false);
    let fs = quant_server(&artifact, true);

    // warm both fleets from the same artifact; the quant server must keep
    // every frame in the compressed domain, the oracle none
    let wq = qs.preload(&artifact).expect("preload quant server");
    assert_eq!(wq.installed, N_TASKS);
    assert_eq!(wq.prefilled, N_TASKS, "panels are the serving form");
    assert_eq!(wq.quantized, N_TASKS, "int8 frames must stay compressed");
    assert_eq!(wq.skipped, N_TASKS * (N_SHARDS - 1), "foreign frames skipped per shard");
    let wf = fs.preload(&artifact).expect("preload f32 server");
    assert_eq!(wf.installed, N_TASKS);
    assert_eq!(wf.quantized, 0, "forced-f32 must not hold quantized panels");

    let rounds = 5usize;
    let (preds, _) = with_listener(&qs, |addr| {
        let mut c = Client::connect(addr);
        let mut out = Vec::new();
        for r in 0..rounds {
            for t in 0..N_TASKS {
                out.push(ask(&mut c, (r * N_TASKS + t) as u64, t, probe_tokens(t, r)));
            }
        }
        out
    });
    let (oracle, _) = with_listener(&fs, |addr| {
        let mut c = Client::connect(addr);
        let mut out = Vec::new();
        for r in 0..rounds {
            for t in 0..N_TASKS {
                out.push(ask(&mut c, (r * N_TASKS + t) as u64, t, probe_tokens(t, r)));
            }
        }
        out
    });
    assert_eq!(preds, oracle, "compressed-domain predictions diverged from the f32 path");
    for (x, &p) in preds.iter().enumerate() {
        let t = x % N_TASKS;
        assert_eq!(p, (t % VOCAB) as i32, "request {x}: wrong class for task {t}");
    }

    // both fleets served warm: no cold fills after preload
    let sq = qs.stop().expect("stop quant server");
    assert_eq!(sq.cache_misses, 0, "preloaded tasks must not cold-fill");
    assert!(sq.cache_hits >= (rounds * N_TASKS) as u64 / 2, "hits: {}", sq.cache_hits);
    assert_eq!(sq.errors, 0);
    let sf = fs.stop().expect("stop f32 server");
    assert_eq!(sf.native_fills, 0, "f32 path must not count native fills");
    let _ = std::fs::remove_file(&artifact);
}

#[test]
fn cold_fill_serving_matches_f32_oracle_without_preload() {
    let artifact = fixture_artifact("coldfill");
    let qs = quant_server(&artifact, false);
    let fs = quant_server(&artifact, true);
    let (preds, _) = with_listener(&qs, |addr| {
        let mut c = Client::connect(addr);
        (0..N_TASKS).map(|t| ask(&mut c, t as u64, t, probe_tokens(t, 0))).collect::<Vec<_>>()
    });
    let (oracle, _) = with_listener(&fs, |addr| {
        let mut c = Client::connect(addr);
        (0..N_TASKS).map(|t| ask(&mut c, t as u64, t, probe_tokens(t, 0))).collect::<Vec<_>>()
    });
    assert_eq!(preds, oracle, "cold-filled predictions diverged from the f32 path");
    let sq = qs.stop().expect("stop quant server");
    assert_eq!(sq.cache_misses, N_TASKS as u64, "one cold fill per task");
    assert_eq!(sq.native_fills, N_TASKS as u64, "int8 cold fills run the native int8 GEMM");
    let sf = fs.stop().expect("stop f32 server");
    assert_eq!(sf.native_fills, 0);
    let _ = std::fs::remove_file(&artifact);
}

#[test]
fn chaos_kill_restart_rewarms_quantized_panels_and_keeps_predictions() {
    let artifact = fixture_artifact("chaos");
    let chaos = Chaos::new(ChaosCfg {
        seed: 0xC0FFEE,
        window: 8,
        panics: 1,
        kills: 1,
        ..ChaosCfg::default()
    });
    let cfg = qserve_cfg(artifact.clone(), false);
    let ch = chaos.clone();
    let server = Server::start_with(&server_cfg(), move |shard| {
        ch.factory_gate()?;
        Ok(ch.wrap(QuantEngine::new(cfg.clone(), shard)?))
    })
    .expect("start chaos quant server");
    // park the artifact: supervisor restarts re-warm replacements from it
    let ws = server.preload(&artifact).expect("preload");
    assert_eq!(ws.installed, N_TASKS);
    assert_eq!(ws.quantized, N_TASKS);

    let ((), _report) = with_listener(&server, |addr| {
        let mut c = Client::connect(addr);
        // hammer until the fault schedule is spent: kills/panics surface
        // as Failed replies or brief rejections, never hangs or resets
        let mut id = 0u64;
        let t0 = std::time::Instant::now();
        while !chaos.exhausted() {
            assert!(t0.elapsed() < Duration::from_secs(60), "chaos schedule never fired");
            for t in 0..N_TASKS {
                c.send(&request(id, t, probe_tokens(t, id as usize)));
                id += 1;
            }
            for _ in 0..N_TASKS {
                let _ = c.recv(); // any typed reply is fine mid-chaos
            }
        }
        // post-chaos: the restarted shard re-warmed from the parked
        // artifact, so every task must predict its engineered class again
        // (retry through restart backoff — replies stay typed throughout)
        for t in 0..N_TASKS {
            let want = (t % VOCAB) as i32;
            let mut got = None;
            for _attempt in 0..200 {
                id += 1;
                c.send(&request(id, t, probe_tokens(t, 1)));
                match c.recv() {
                    Msg::ReplyOk { token, .. } => {
                        got = Some(token);
                        break;
                    }
                    Msg::ReplyErr { .. } => std::thread::sleep(Duration::from_millis(10)),
                    other => panic!("task {t}: unexpected {other:?}"),
                }
            }
            assert_eq!(got, Some(want), "task {t} lost its panels after chaos");
        }
    });

    let stats = server.stop().expect("stop chaos server");
    assert!(stats.restarts >= 1, "chaos injected no restart — the test is vacuous");
    let _ = std::fs::remove_file(&artifact);
}
