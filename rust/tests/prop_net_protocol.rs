//! Randomized properties of the MCNP1 socket protocol (`net::protocol`,
//! `net::conn`), mirroring `prop_codec.rs`: every message variant
//! round-trips bit-exactly through frame encode → deframe; streams split
//! at every byte boundary reassemble identically; and hostile input —
//! truncations, single-bit flips, oversized length fields, arbitrary byte
//! soup — always surfaces as `Err` or "wait for more bytes", never a
//! panic, never a silent mis-decode, never unbounded buffering. The
//! worked hex example from `docs/PROTOCOL.md` §4 is pinned here byte for
//! byte (`protocol_spec_worked_example_decodes`).

use mcnc::net::conn::Conn;
use mcnc::net::protocol::{
    self, encode_body, encode_frame, Deframer, Msg, ERR_DEADLINE, ERR_FAILED, ERR_REJECTED,
    MAX_ERR_LEN, NET_MAGIC, NET_MAX_FRAME,
};
use mcnc::prop_assert;
use mcnc::util::prop::{run_prop, Gen};

fn arb_u64(g: &mut Gen) -> u64 {
    *g.pick(&[
        0u64,
        1,
        127,
        128,
        300,
        16_383,
        16_384,
        u32::MAX as u64,
        u64::MAX,
        g.usize(0, 1_000_000) as u64,
    ])
}

fn arb_i32(g: &mut Gen) -> i32 {
    *g.pick(&[0i32, 1, -1, 7, -128, i32::MAX, i32::MIN, g.usize(0, 65_535) as i32])
}

fn arb_string(g: &mut Gen) -> String {
    let base = g.pick(&["", "queue full", "shard 3 unavailable", "é✓ ünicode"]).to_string();
    let pad = g.usize(0, 64);
    format!("{base}{}", "x".repeat(pad))
}

fn arb_msg(g: &mut Gen) -> Msg {
    match g.usize(0, 5) {
        0 => Msg::Req {
            id: arb_u64(g),
            task: arb_u64(g),
            tokens: {
                let n = g.usize(0, 48);
                (0..n).map(|_| arb_i32(g)).collect()
            },
            deadline_us: arb_u64(g),
        },
        1 => Msg::ReplyOk {
            id: arb_u64(g),
            trace: arb_u64(g),
            token: arb_i32(g),
            batch_rows: arb_u64(g),
            latency_us: arb_u64(g),
        },
        2 => Msg::ReplyErr {
            id: arb_u64(g),
            trace: arb_u64(g),
            code: *g.pick(&[ERR_REJECTED, ERR_FAILED, ERR_DEADLINE]),
            msg: arb_string(g),
        },
        3 => Msg::Ping { nonce: arb_u64(g) },
        4 => Msg::Pong { nonce: arb_u64(g) },
        _ => Msg::ConnErr { msg: arb_string(g) },
    }
}

/// Drain a deframer, collecting messages until `Ok(None)` or `Err`.
fn drain(d: &mut Deframer) -> Result<Vec<Msg>, anyhow::Error> {
    let mut out = Vec::new();
    while let Some(m) = d.next()? {
        out.push(m);
    }
    Ok(out)
}

#[test]
fn all_variants_roundtrip_bit_exactly() {
    run_prop("net_roundtrip", 200, |g| {
        let msgs: Vec<Msg> = (0..g.usize(1, 8)).map(|_| arb_msg(g)).collect();
        let mut wire = Vec::new();
        for m in &msgs {
            wire.extend_from_slice(&encode_frame(m));
        }
        let mut d = Deframer::new();
        d.push(&wire);
        let back = drain(&mut d).map_err(|e| format!("pristine stream failed: {e:#}"))?;
        prop_assert!(back == msgs, "roundtrip mismatch: {} in, {} out", msgs.len(), back.len());
        prop_assert!(d.buffered() == 0, "{} bytes left after a whole stream", d.buffered());
        // bit-exact deterministic re-encode
        let mut wire2 = Vec::new();
        for m in &back {
            wire2.extend_from_slice(&encode_frame(m));
        }
        prop_assert!(wire2 == wire, "re-encode drifted");
        Ok(())
    });
}

#[test]
fn split_at_every_byte_boundary_reassembles() {
    // exhaustive split points on a fixed stream, not sampled ones: every
    // prefix/suffix pair must decode to the same messages
    let msgs = vec![
        Msg::Req { id: 17, task: 3, tokens: vec![5, -2], deadline_us: 0 },
        Msg::Ping { nonce: u64::MAX },
        Msg::ReplyErr { id: 1, trace: 2, code: ERR_REJECTED, msg: "full".into() },
    ];
    let mut wire = Vec::new();
    for m in &msgs {
        wire.extend_from_slice(&encode_frame(m));
    }
    for cut in 0..=wire.len() {
        let mut d = Deframer::new();
        let mut got = Vec::new();
        d.push(&wire[..cut]);
        got.extend(drain(&mut d).unwrap_or_else(|e| panic!("prefix of {cut} bytes: {e:#}")));
        d.push(&wire[cut..]);
        got.extend(drain(&mut d).unwrap_or_else(|e| panic!("suffix after {cut} bytes: {e:#}")));
        assert_eq!(got, msgs, "split at byte {cut}");
        assert_eq!(d.buffered(), 0, "split at byte {cut} left residue");
    }
}

#[test]
fn random_chunking_through_a_conn_reassembles() {
    run_prop("net_chunked_conn", 120, |g| {
        let msgs: Vec<Msg> = (0..g.usize(1, 6)).map(|_| arb_msg(g)).collect();
        let mut wire = NET_MAGIC.to_vec();
        for m in &msgs {
            wire.extend_from_slice(&encode_frame(m));
        }
        let mut c = Conn::new();
        let mut got = Vec::new();
        let mut off = 0;
        while off < wire.len() {
            let n = g.usize(1, 9).min(wire.len() - off);
            got.extend(
                c.on_bytes(&wire[off..off + n]).map_err(|e| format!("chunk at {off}: {e:#}"))?,
            );
            off += n;
        }
        prop_assert!(got == msgs, "conn reassembly mismatch");
        Ok(())
    });
}

#[test]
fn truncations_never_panic_and_never_fabricate() {
    run_prop("net_truncation", 200, |g| {
        let msgs: Vec<Msg> = (0..g.usize(1, 5)).map(|_| arb_msg(g)).collect();
        let mut wire = Vec::new();
        for m in &msgs {
            wire.extend_from_slice(&encode_frame(m));
        }
        let cut = g.usize(0, wire.len().saturating_sub(1));
        let mut d = Deframer::new();
        d.push(&wire[..cut]);
        // a truncated pristine stream yields some prefix of the original
        // messages and then waits — it must never error or invent frames
        let got = drain(&mut d).map_err(|e| format!("truncated stream errored: {e:#}"))?;
        prop_assert!(got.len() <= msgs.len(), "fabricated messages");
        prop_assert!(got[..] == msgs[..got.len()], "prefix mismatch after truncation at {cut}");
        // decode_body on truncated bodies: error, never panic
        for m in &msgs {
            let body = encode_body(m);
            let keep = g.usize(0, body.len().saturating_sub(1));
            prop_assert!(
                protocol::decode_body(&body[..keep]).is_err(),
                "strict body prefix of {keep} bytes decoded"
            );
        }
        Ok(())
    });
}

#[test]
fn single_bit_flips_never_silently_misdecode() {
    run_prop("net_bitflip", 300, |g| {
        let msg = arb_msg(g);
        let mut frame = encode_frame(&msg);
        let bit = g.usize(0, frame.len() * 8 - 1);
        frame[bit / 8] ^= 1 << (bit % 8);
        let mut d = Deframer::new();
        d.push(&frame);
        // outcomes: Err (detected), Ok(None) (waiting for phantom bytes),
        // or a decoded message that differs from the original. What must
        // never happen: a panic, or the original message resurrected from
        // corrupt bytes (CRC-32 catches every single-bit error in the
        // covered region).
        match drain(&mut d) {
            Err(_) => {}
            Ok(got) => {
                prop_assert!(
                    !got.contains(&msg),
                    "bit {bit} flipped yet the original message decoded"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn arbitrary_byte_soup_never_panics_and_buffering_stays_bounded() {
    run_prop("net_soup", 300, |g| {
        let n = g.usize(0, 2048);
        let bytes: Vec<u8> = (0..n).map(|_| g.usize(0, 255) as u8).collect();
        let mut d = Deframer::new();
        let mut off = 0;
        let mut dead = false;
        while off < bytes.len() {
            let k = g.usize(1, 64).min(bytes.len() - off);
            d.push(&bytes[off..off + k]);
            off += k;
            match drain(&mut d) {
                Ok(_) => {}
                Err(_) => {
                    dead = true;
                    break; // a real connection closes here
                }
            }
        }
        prop_assert!(
            dead || d.buffered() <= NET_MAX_FRAME + 14,
            "deframer buffered {} bytes of garbage",
            d.buffered()
        );
        // same soup through a Conn (random bad preambles usually die at
        // the handshake; NET_MAGIC-prefixed soup dies at the first frame)
        let mut c = Conn::new();
        let mut wire = if g.bool() { NET_MAGIC.to_vec() } else { Vec::new() };
        wire.extend_from_slice(&bytes);
        let _ = c.on_bytes(&wire); // must not panic, either way
        Ok(())
    });
}

#[test]
fn oversized_length_fields_fail_before_buffering() {
    run_prop("net_oversize", 100, |g| {
        let claim = (NET_MAX_FRAME as u64 + 1).saturating_add(g.usize(0, 1 << 30) as u64);
        let mut wire = Vec::new();
        mcnc::codec::container::put_varint(&mut wire, claim);
        let mut d = Deframer::new();
        d.push(&wire);
        prop_assert!(d.next().is_err(), "length {claim} accepted");
        // error strings on the wire are bounded too
        let huge = "a".repeat(MAX_ERR_LEN * 3);
        let frame = encode_frame(&Msg::ConnErr { msg: huge });
        prop_assert!(
            frame.len() <= MAX_ERR_LEN + 16,
            "encoder emitted an unbounded error frame ({} bytes)",
            frame.len()
        );
        Ok(())
    });
}

/// Pins the worked example of docs/PROTOCOL.md §4: these exact bytes must
/// decode to these exact messages (and re-encode identically) on every
/// host, forever. Changing the wire format requires bumping the preamble
/// version and rewriting the spec, not editing this test.
#[test]
fn protocol_spec_worked_example_decodes() {
    assert_eq!(&NET_MAGIC[..], b"MCNP1\n");
    assert_eq!(NET_MAGIC.to_vec(), vec![0x4d, 0x43, 0x4e, 0x50, 0x31, 0x0a]);

    let req_frame: Vec<u8> = vec![
        0x0d, // body_len = 13
        0x01, // MSG_REQ
        0x11, // id = 17
        0x03, // task = 3
        0x02, // n_tokens = 2
        0x05, 0x00, 0x00, 0x00, // token 5
        0xfe, 0xff, 0xff, 0xff, // token -2
        0x00, // deadline_us = 0 (none)
        0xb5, 0xec, 0x62, 0x96, // crc32(body) LE
    ];
    let req = Msg::Req { id: 17, task: 3, tokens: vec![5, -2], deadline_us: 0 };
    assert_eq!(encode_frame(&req), req_frame);

    let ok_frame: Vec<u8> = vec![
        0x0b, // body_len = 11
        0x02, // MSG_REPLY_OK
        0x11, // id = 17 (echoed)
        0xac, 0x02, // trace = 300
        0x07, 0x00, 0x00, 0x00, // token = 7
        0x04, // batch_rows = 4
        0xd2, 0x09, // latency_us = 1234
        0x15, 0x1d, 0x4e, 0xb3, // crc32(body) LE
    ];
    let ok = Msg::ReplyOk { id: 17, trace: 300, token: 7, batch_rows: 4, latency_us: 1234 };
    assert_eq!(encode_frame(&ok), ok_frame);

    // and the full conversation decodes through a Conn byte-for-byte
    let mut wire = NET_MAGIC.to_vec();
    wire.extend_from_slice(&req_frame);
    let mut c = Conn::new();
    assert_eq!(c.on_bytes(&wire).expect("spec bytes"), vec![req]);
    let mut d = Deframer::new();
    d.push(&ok_frame);
    assert_eq!(d.next().expect("spec reply"), Some(ok));
}
