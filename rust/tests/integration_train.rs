//! Training-stack integration: Trainer + checkpoints + pruning substrate
//! over real PJRT executables.

use std::sync::Arc;

use mcnc::baselines::{sparsity_for_size, topk_mask, Platon};
use mcnc::data::{Dataset, Split, SynthVision};
use mcnc::runtime::{artifacts_dir, Session};
use mcnc::tensor::Tensor;
use mcnc::train::{self, Checkpoint, LrSchedule, TrainCfg, TrainState};

fn session() -> Option<Session> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return None;
    }
    Some(Session::open(&dir).unwrap())
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let Some(sess) = session() else { return };
    let mut st = TrainState::new(&sess, "mlp_mcnc02_train", 9).unwrap();
    let data: Arc<dyn Dataset> = Arc::new(SynthVision::new(4, 10, 28, 28, 1));
    let cfg = TrainCfg { steps: 15, batch: 128, schedule: LrSchedule::Const(0.05), ..TrainCfg::default() };
    train::run(&mut st, Arc::clone(&data), &cfg).unwrap();
    let (x, y) = data.batch(Split::Val, 0, 128);
    let before = st.eval(x.clone(), y.clone()).unwrap();

    let dir = std::env::temp_dir().join(format!("mcnc_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mlp.mcnc");
    let ck = Checkpoint::from_state(&st);
    ck.save(&path).unwrap();

    // checkpoint stores only the compressed representation
    assert_eq!(ck.stored_params() as f64, 540.0 + st.get("raw").unwrap().numel() as f64);
    let dense_bytes = 268_800 * 4;
    assert!(ck.stored_bytes() * 50 < dense_bytes, "checkpoint not compressed");

    // fresh state from the same seed + restore == identical eval
    let mut st2 = TrainState::new(&sess, "mlp_mcnc02_train", 9).unwrap();
    Checkpoint::load(&path).unwrap().restore(&mut st2).unwrap();
    let after = st2.eval(x, y).unwrap();
    assert_eq!(before.loss.to_bits(), after.loss.to_bits(), "restore is not bitwise");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn magnitude_pruning_pipeline() {
    let Some(sess) = session() else { return };
    let mut st = TrainState::new(&sess, "mlp_dense_train", 3).unwrap();
    let data: Arc<dyn Dataset> = Arc::new(SynthVision::new(4, 10, 28, 28, 1));
    let cfg = TrainCfg { steps: 25, batch: 128, schedule: LrSchedule::Const(0.005), ..TrainCfg::default() };
    let dense = train::run(&mut st, Arc::clone(&data), &cfg).unwrap();

    // prune to 10% model size (paper's accounting: 1.5x sparsity)
    let theta = st.get("theta_c").unwrap().f32s().unwrap().to_vec();
    let sparsity = sparsity_for_size(0.10);
    let mask = topk_mask(&theta, sparsity);
    let kept = mask.iter().filter(|&&m| m == 1.0).count();
    assert!((kept as f64 / theta.len() as f64 - (1.0 - sparsity as f64)).abs() < 0.01);
    st.set("mask", Tensor::from_f32(mask, &[theta.len()]).unwrap()).unwrap();
    st.reset_optimizer();

    // pruned accuracy drops, finetuning recovers some
    let (x, y) = data.batch(Split::Val, 0, 128);
    let pruned = st.eval(x.clone(), y.clone()).unwrap();
    assert!(pruned.acc <= dense.final_val_acc() + 0.02);
    let ft_cfg = TrainCfg { steps: 15, batch: 128, schedule: LrSchedule::Const(0.002), ..TrainCfg::default() };
    train::run(&mut st, Arc::clone(&data), &ft_cfg).unwrap();
    let recovered = st.eval(x, y).unwrap();
    assert!(
        recovered.acc >= pruned.acc - 0.02,
        "finetune made things worse: {} -> {}",
        pruned.acc,
        recovered.acc
    );
}

#[test]
fn platon_importance_pipeline() {
    let Some(sess) = session() else { return };
    let mut st = TrainState::new(&sess, "mlp_dense_train", 5).unwrap();
    let data: Arc<dyn Dataset> = Arc::new(SynthVision::new(4, 10, 28, 28, 1));
    // a few warmup steps so gradients are meaningful
    for step in 0..5 {
        let (x, y) = data.batch(Split::Train, step, 128);
        st.step(x, y, 0.005).unwrap();
    }
    let dc = st.get("theta_c").unwrap().numel();
    let mut platon = Platon::new(dc, 0.85, 0.95);
    for step in 5..10 {
        let (x, y) = data.batch(Split::Train, step, 128);
        let imp = st.importance(x, y).unwrap();
        platon.update(&imp);
    }
    let mask = platon.mask(0.9);
    assert_eq!(mask.iter().filter(|&&m| m == 1.0).count(), (dc as f64 * 0.1).round() as usize);
    // masked model still runs
    st.set("mask", Tensor::from_f32(mask, &[dc]).unwrap()).unwrap();
    let (x, y) = data.batch(Split::Val, 0, 128);
    let out = st.eval(x, y).unwrap();
    assert!(out.loss.is_finite());
}

#[test]
fn lm_peft_adapters_improve_on_task() {
    let Some(sess) = session() else { return };
    use mcnc::data::MarkovLm;
    // Base LM pretrained briefly on the base chain
    let base_chain = MarkovLm::base(11, 128, 32);
    let mut dense = TrainState::new(&sess, "lm_dense_train", 21).unwrap();
    let base_data: Arc<dyn Dataset> = Arc::new(base_chain.clone());
    let cfg = TrainCfg { steps: 30, batch: 16, schedule: LrSchedule::Const(0.003), ..TrainCfg::default() };
    let hist = train::run(&mut dense, Arc::clone(&base_data), &cfg).unwrap();
    assert!(hist.losses.last().unwrap() < &hist.losses[0]);

    // PEFT on a shifted task: adapter training must beat the frozen base.
    // (θ0 here is the init-law base, not the pretrained weights — both
    // adapter and baseline see the same θ0, so the comparison is fair.)
    let task = MarkovLm::task(&base_chain, 1, 0.8);
    let task_data: Arc<dyn Dataset> = Arc::new(task);
    let mut peft = TrainState::new(&sess, "lm_mcnclora8_train", 21).unwrap();
    let (x, y) = task_data.batch(Split::Val, 0, 16);
    let frozen = peft.eval(x.clone(), y.clone()).unwrap();
    let cfg2 = TrainCfg { steps: 40, batch: 16, schedule: LrSchedule::Const(0.02), ..TrainCfg::default() };
    train::run(&mut peft, Arc::clone(&task_data), &cfg2).unwrap();
    let tuned = peft.eval(x, y).unwrap();
    assert!(
        tuned.loss < frozen.loss - 0.05,
        "adapter did not adapt: {} -> {}",
        frozen.loss,
        tuned.loss
    );
}
