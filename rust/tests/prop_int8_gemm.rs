//! The int8 GEMM oracle-parity battery — the contract that lets serving
//! run GEMMs in the compressed domain without revalidating numerics:
//!
//! * **Analytic accuracy vs the f32 path.** Forced-scalar [`gemm_q`] over
//!   quantized panels must land within a *pinned analytic bound* of the
//!   f32 oracle (scalar `gemm` on the original A and the dequantized B).
//!   B's quantization cancels — both sides consume the same
//!   symbols×scales — so the bound is exactly the A-side absmax
//!   quantization error plus float-rounding slop, derived per element
//!   from the recomputed per-group A scales:
//!
//!   `|Δ[i,j]| ≤ Σ_g 0.51·sa_ig·Σ_{k∈g}|B̃[k,j]|              (A rounding)
//!             + Σ_{g: sa_ig=0} Σ_{k∈g}|a[i,k]|·|B̃[k,j]|     (underflow→0)
//!             + (3G+K+8)·ε·Σ_k(|a[i,k]|+0.51·sa)·|B̃[k,j]|   (f32 rounding)
//!             + (G+1)·2·MIN_POSITIVE`                         (denormal slop)
//!
//! * **Bit-exact cross-ISA dispatch.** Dispatched [`gemm_q`] (AVX2/NEON
//!   when available) must equal forced-scalar bit-for-bit on every
//!   element — including the misaligned-scale-group shapes that silently
//!   fall back to the scalar kernel over the SIMD panel layout. This is
//!   what the f32 kernels can *not* promise (they allow fused-madd ulp
//!   drift); the int8 path's i32 inner sums and fixed float edge sequence
//!   make exactness testable, so it is pinned, not bounded.
//!
//! * **Exhaustive remainder tiles.** Every `m % MR` × `n % NR_Q` residue
//!   the microkernels can see, swept deterministically.
//!
//! * **Hostile inputs.** Absmax-0 blocks, denormal scales, all-saturated
//!   ±qmax blocks, and NaN/±inf in the f32 sources never panic, keep
//!   scalar/dispatched parity, and stay in-bound wherever finite.

use mcnc::codec::quantizer;
use mcnc::mcnc::kernel::{self, Isa};
use mcnc::prop_assert;
use mcnc::util::prng::Stream;
use mcnc::util::prop::{run_prop, Gen};

/// anyhow → property-error adapter.
fn e<T>(r: anyhow::Result<T>) -> Result<T, String> {
    r.map_err(|x| format!("{x:#}"))
}

/// The f32 oracle: forced-scalar `gemm` on (original A, dequantized B).
fn f32_oracle(a: &[f32], bdeq: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let pb = kernel::pack_b_for(Isa::Scalar, bdeq, k, n);
    let mut c = vec![f32::NAN; m * n];
    kernel::gemm(a, m, &pb, &mut c);
    c
}

/// Per-element pinned analytic bound (see module docs). `sa[g]` must be
/// the *recomputed* A-row group scales — `absmax/127` exactly as
/// `quantize_a` derives them — so the bound is independent of the
/// implementation under test.
fn analytic_tol(a_row: &[f32], bdeq: &[f32], j: usize, n: usize, kg: usize, sa: &[f32]) -> f64 {
    let k = a_row.len();
    let mut quant = 0.0f64;
    let mut under = 0.0f64;
    let mut mag = 0.0f64;
    for (g, &sa_g) in sa.iter().enumerate() {
        let sa_g = sa_g as f64;
        for kk in g * kg..((g + 1) * kg).min(k) {
            let bd = (bdeq[kk * n + j] as f64).abs();
            let av = (a_row[kk] as f64).abs();
            quant += 0.51 * sa_g * bd;
            mag += (av + 0.51 * sa_g) * bd;
            if sa_g == 0.0 {
                under += av * bd;
            }
        }
    }
    // ≤3 float roundings per group on the quant edge (scale product,
    // rescale multiply, accumulate add), K on the oracle's accumulation
    let groups = sa.len() as f64;
    quant
        + under
        + (3.0 * groups + k as f64 + 8.0) * f32::EPSILON as f64 * mag
        + (groups + 1.0) * 2.0 * f32::MIN_POSITIVE as f64
}

/// Recompute row `i`'s per-group A scales exactly as `quantize_a` does:
/// scalar absmax over the group, divided by 127 in f32 (underflow → 0.0).
fn a_scales(a: &[f32], i: usize, k: usize, kg: usize) -> Vec<f32> {
    let row = &a[i * k..i * k + k];
    (0..k.div_ceil(kg))
        .map(|g| kernel::absmax_for(Isa::Scalar, &row[g * kg..((g + 1) * kg).min(k)]) / 127.0)
        .collect()
}

/// Quantize B, pack it for `isa`, quantize A to match, run `gemm_q`.
/// Returns (C, dequantized B, group_rows).
fn quant_gemm(
    isa: Isa,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    bits: u32,
    block: usize,
) -> Result<(Vec<f32>, Vec<f32>, usize), String> {
    let q = quantizer::quantize_with(Isa::Scalar, b, bits, block);
    let pq = e(kernel::pack_bq_for(isa, k, n, bits, block, &q.scales, &q.symbols))?;
    let qa = kernel::quantize_a(a, m, k, pq.group_rows());
    let mut c = vec![f32::NAN; m * n];
    kernel::gemm_q(&qa, &pq, &mut c);
    Ok((c, quantizer::dequantize(&q), pq.group_rows()))
}

/// An admissible scale block for a `[k, n]` weight: whole rows or the
/// whole tensor (the only shapes the panel layout accepts).
fn admissible_block(g: &mut Gen, k: usize, n: usize) -> usize {
    *g.pick(&[n, 2 * n, 4 * n, k * n])
}

#[test]
fn forced_scalar_int8_gemm_within_pinned_analytic_bound() {
    run_prop("int8_gemm_analytic_bound", 60, |g| {
        let m = g.usize(1, 12);
        let k = g.usize(1, 48);
        let n = g.usize(1, 24);
        let bits = *g.pick(&[4u32, 8]);
        let block = admissible_block(g, k, n);
        let a = g.vec_f32(m * k, -2.0, 2.0);
        let b = g.vec_f32(k * n, -1.0, 1.0);
        let (cq, bdeq, kg) = quant_gemm(Isa::Scalar, &a, &b, m, k, n, bits, block)?;
        let cf = f32_oracle(&a, &bdeq, m, k, n);
        for i in 0..m {
            let sa = a_scales(&a, i, k, kg);
            for j in 0..n {
                let (got, want) = (cq[i * n + j] as f64, cf[i * n + j] as f64);
                let tol = analytic_tol(&a[i * k..(i + 1) * k], &bdeq, j, n, kg, &sa);
                let diff = (got - want).abs();
                prop_assert!(
                    diff <= tol,
                    "({m},{k},{n}) bits={bits} block={block} [{i},{j}]: \
                     quant {got:e} vs f32 {want:e} (diff {diff:e} > tol {tol:e})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn dispatched_int8_gemm_bit_identical_to_forced_scalar() {
    run_prop("int8_dispatched_vs_scalar", 60, |g| {
        let m = g.usize(1, 12);
        let k = g.usize(1, 48);
        let n = g.usize(1, 24);
        let bits = *g.pick(&[4u32, 8]);
        // n → kg=1 (misaligned for every SIMD ku: scalar-kernel fallback
        // over the SIMD layout), 2n/4n → ku-aligned groups, k·n → one group
        let block = *g.pick(&[n, 2 * n, 3 * n, 4 * n, k * n]);
        let a = g.vec_f32(m * k, -2.0, 2.0);
        let b = g.vec_f32(k * n, -1.0, 1.0);
        let q = quantizer::quantize_with(Isa::Scalar, &b, bits, block);
        let ps = e(kernel::pack_bq_for(Isa::Scalar, k, n, bits, block, &q.scales, &q.symbols))?;
        let pd = e(kernel::pack_bq(k, n, bits, block, &q.scales, &q.symbols))?;
        prop_assert!(ps.isa() == Isa::Scalar, "scalar override leaked {:?}", ps.isa());
        prop_assert!(kernel::available(pd.isa()), "dispatched to unavailable {:?}", pd.isa());
        prop_assert!(
            ps.group_rows() == pd.group_rows() && ps.bits() == pd.bits(),
            "layout metadata diverged between ISAs"
        );
        let qa = kernel::quantize_a(&a, m, k, pd.group_rows());
        let mut cs = vec![f32::NAN; m * n];
        let mut cd = vec![f32::NAN; m * n];
        kernel::gemm_q(&qa, &ps, &mut cs);
        kernel::gemm_q(&qa, &pd, &mut cd);
        for i in 0..m {
            for j in 0..n {
                let (s, d) = (cs[i * n + j], cd[i * n + j]);
                prop_assert!(
                    s.to_bits() == d.to_bits(),
                    "({m},{k},{n}) bits={bits} block={block} [{i},{j}]: \
                     {:?} {d:e} != scalar {s:e}",
                    pd.isa()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn int8_parity_covers_every_remainder_tile_shape() {
    // exhaustive m residues for MR=4 and n residues for NR_Q=8: m ∈ 1..=13
    // hits every m % 4 including multi-tile, n ∈ 1..=17 ∪ {31, 32, 33}
    // hits every n % 8 including full-panel and one-past boundaries; block
    // n (scalar fallback), 4n (ku-aligned) and k·n (single group) steer
    // all three gemm_q admission branches.
    for m in 1..=13usize {
        for n in (1..=17usize).chain([31, 32, 33]) {
            for k in [1usize, 7, 33] {
                let a = Stream::new((m * 131 + n * 17 + k) as u64).uniform_f32(m * k, -2.0, 2.0);
                let b = Stream::new((m + n * 29 + k * 5) as u64).uniform_f32(k * n, -1.0, 1.0);
                for block in [n, 4 * n, k * n] {
                    let q = quantizer::quantize_with(Isa::Scalar, &b, 8, block);
                    let ps =
                        kernel::pack_bq_for(Isa::Scalar, k, n, 8, block, &q.scales, &q.symbols)
                            .unwrap();
                    let pd = kernel::pack_bq(k, n, 8, block, &q.scales, &q.symbols).unwrap();
                    let qa = kernel::quantize_a(&a, m, k, pd.group_rows());
                    let mut cs = vec![f32::NAN; m * n];
                    let mut cd = vec![f32::NAN; m * n];
                    kernel::gemm_q(&qa, &ps, &mut cs);
                    kernel::gemm_q(&qa, &pd, &mut cd);
                    for i in 0..m {
                        for j in 0..n {
                            let (s, d) = (cs[i * n + j], cd[i * n + j]);
                            assert!(
                                s.to_bits() == d.to_bits(),
                                "({m},{k},{n}) block={block} [{i},{j}]: {d:e} != scalar {s:e}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn hostile_inputs_never_panic_and_stay_in_bound() {
    run_prop("int8_hostile_inputs", 60, |g| {
        let m = g.usize(1, 6);
        let k = g.usize(1, 24);
        let n = g.usize(1, 12);
        let block = if g.bool() { n } else { k * n };
        let mut a = g.vec_f32(m * k, -2.0, 2.0);
        let mut b = g.vec_f32(k * n, -1.0, 1.0);
        let mode = g.usize(0, 5);
        let mut finite = true;
        match mode {
            0 => {
                // absmax-0 scale blocks: zero one whole block of B and one
                // whole A-quantization group of a row
                let blk = g.usize(0, (k * n - 1) / block);
                for v in &mut b[blk * block..((blk + 1) * block).min(k * n)] {
                    *v = 0.0;
                }
                let kg = if block % n == 0 { block / n } else { k };
                let (i, gg) = (g.usize(0, m - 1), g.usize(0, (k - 1) / kg));
                for kk in gg * kg..((gg + 1) * kg).min(k) {
                    a[i * k + kk] = 0.0;
                }
            }
            1 => {
                // denormal scales on both sides
                for v in &mut b {
                    *v *= 1.0e-42;
                }
                let i = g.usize(0, m - 1);
                for v in &mut a[i * k..(i + 1) * k] {
                    *v *= 1.0e-42;
                }
            }
            2 => {
                // all-saturated blocks: |v| == absmax everywhere → every
                // symbol lands on ±qmax (±127 at 8 bits)
                for (x, v) in b.iter_mut().enumerate() {
                    *v = if x % 2 == 0 { 0.75 } else { -0.75 };
                }
            }
            3 => {
                a[g.usize(0, m * k - 1)] = f32::NAN;
            }
            4 => {
                a[g.usize(0, m * k - 1)] = f32::INFINITY;
                finite = false;
            }
            _ => {
                b[g.usize(0, k * n - 1)] = if g.bool() { f32::NAN } else { f32::NEG_INFINITY };
                finite = false;
            }
        }
        // none of this may panic
        let q = quantizer::quantize_with(Isa::Scalar, &b, 8, block);
        let ps = e(kernel::pack_bq_for(Isa::Scalar, k, n, 8, block, &q.scales, &q.symbols))?;
        let pd = e(kernel::pack_bq(k, n, 8, block, &q.scales, &q.symbols))?;
        let qa = kernel::quantize_a(&a, m, k, pd.group_rows());
        let mut cs = vec![f32::NAN; m * n];
        let mut cd = vec![f32::NAN; m * n];
        kernel::gemm_q(&qa, &ps, &mut cs);
        kernel::gemm_q(&qa, &pd, &mut cd);
        // dispatched stays bit-identical to scalar even on hostile inputs
        for (x, (s, d)) in cs.iter().zip(&cd).enumerate() {
            prop_assert!(
                s.to_bits() == d.to_bits(),
                "mode {mode} ({m},{k},{n}) block={block} [{x}]: {d:e} != scalar {s:e}"
            );
        }
        if !finite {
            return Ok(()); // inf-poisoned: only the no-panic + parity contract
        }
        // NaN in A quantizes to symbol 0 under a NaN-ignoring absmax, so
        // the quantized output stays finite (documented contract) …
        for (x, v) in cs.iter().enumerate() {
            prop_assert!(v.is_finite(), "mode {mode} [{x}]: non-finite {v} from finite scales");
        }
        if mode == 3 {
            return Ok(()); // … but the f32 oracle goes NaN: bound not comparable
        }
        let bdeq = quantizer::dequantize(&q);
        let cf = f32_oracle(&a, &bdeq, m, k, n);
        let kg = pd.group_rows();
        for i in 0..m {
            let sa = a_scales(&a, i, k, kg);
            for j in 0..n {
                let (got, want) = (cs[i * n + j] as f64, cf[i * n + j] as f64);
                let tol = analytic_tol(&a[i * k..(i + 1) * k], &bdeq, j, n, kg, &sa);
                prop_assert!(
                    (got - want).abs() <= tol,
                    "mode {mode} ({m},{k},{n}) block={block} [{i},{j}]: \
                     quant {got:e} vs f32 {want:e} (tol {tol:e})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn straddling_blocks_and_bad_shapes_error_cleanly() {
    // the panel layout's admission rule: whole rows or whole tensor only
    assert!(kernel::quant_panels_admissible(4, 6, 6));
    assert!(kernel::quant_panels_admissible(4, 6, 12));
    assert!(kernel::quant_panels_admissible(4, 6, 24));
    assert!(kernel::quant_panels_admissible(4, 6, 64), "one block covers the whole tensor");
    assert!(!kernel::quant_panels_admissible(4, 6, 5), "straddles rows");
    assert!(!kernel::quant_panels_admissible(4, 6, 0), "zero block");
    let q = quantizer::quantize_with(Isa::Scalar, &vec![0.5f32; 24], 8, 5);
    let err = kernel::pack_bq_for(Isa::Scalar, 4, 6, 8, 5, &q.scales, &q.symbols).unwrap_err();
    assert!(format!("{err:#}").contains("straddles"), "{err:#}");
    // short symbol stream must error, not zero-pad
    let q = quantizer::quantize_with(Isa::Scalar, &vec![0.5f32; 24], 8, 6);
    let err = kernel::pack_bq_for(Isa::Scalar, 4, 6, 8, 6, &q.scales, &q.symbols[..20]).unwrap_err();
    assert!(format!("{err:#}").contains("symbols"), "{err:#}");
}
