//! Cross-language golden tests: Rust `runtime::init` must reproduce the
//! exact tensors Python's `compile.initlib` synthesizes (fixture generated
//! by the Python twin with seed 123 — see python/tests/goldens_cross.json).
//! First-4 values compare bitwise for the uniform laws; sums tolerate the
//! f64-accumulation + Box-Muller libm ulp differences.

use mcnc::runtime::{artifacts_dir, init, Manifest, Role};
use mcnc::util::json::{self, Json};

fn fixture() -> Option<Json> {
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("python/tests/goldens_cross.json");
    let text = std::fs::read_to_string(path).ok()?;
    Some(json::parse(&text).unwrap())
}

#[test]
fn init_laws_match_python_twin() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let Some(golden) = fixture() else {
        eprintln!("skipping: no goldens_cross.json fixture");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let mut checked = 0;
    for (entry_name, tensors) in golden.as_obj().unwrap() {
        let entry = manifest.get(entry_name).unwrap();
        let reg = entry.registry().unwrap();
        for spec in &entry.inputs {
            if !matches!(spec.role, Role::Static | Role::Trainable) {
                continue;
            }
            let Some(g) = tensors.get(&spec.name) else { continue };
            let t = init::init_tensor(spec.init.as_ref().unwrap(), &spec.shape, &reg, 123)
                .unwrap_or_else(|e| panic!("{entry_name}:{}: {e}", spec.name));
            let v = t.f32s().unwrap();
            assert_eq!(
                v.len(),
                g.get("numel").unwrap().as_usize().unwrap(),
                "{entry_name}:{}",
                spec.name
            );
            let first: Vec<f64> = g
                .get("first")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_f64().unwrap())
                .collect();
            for (i, want) in first.iter().enumerate() {
                let got = v[i] as f64;
                assert!(
                    (got - want).abs() <= want.abs() * 1e-5 + 1e-7,
                    "{entry_name}:{}[{i}]: rust {got} vs python {want}",
                    spec.name
                );
            }
            let sum: f64 = v.iter().map(|&x| x as f64).sum();
            let want_sum = g.get("sum").unwrap().as_f64().unwrap();
            let tol = 1e-4 * (v.len() as f64).sqrt() + want_sum.abs() * 1e-5 + 1e-6;
            assert!(
                (sum - want_sum).abs() <= tol,
                "{entry_name}:{}: sum {sum} vs {want_sum} (tol {tol})",
                spec.name
            );
            checked += 1;
        }
    }
    assert!(checked >= 15, "only {checked} tensors verified");
}
