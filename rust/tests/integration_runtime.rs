//! Cross-layer integration: the PJRT-executed artifacts (L1 Pallas kernel
//! lowered inside L2 jax graphs) must agree with the native Rust mirror,
//! and train steps must actually learn through the runtime boundary.
//!
//! All tests no-op gracefully when `artifacts/` hasn't been built.

use mcnc::mcnc::{GenCfg, Generator};
use mcnc::runtime::{artifacts_dir, init, Role, Session};
use mcnc::tensor::Tensor;
use mcnc::util::prng::{tag, Stream};

fn session() -> Option<Session> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Session::open(&dir).unwrap())
}

/// PJRT generator executable == native Rust generator, same weights.
#[test]
fn pallas_kernel_matches_native_generator() {
    let Some(sess) = session() else { return };
    let entry = sess.entry("gen_mlp02_fwd").unwrap().clone();
    let gen_meta = entry.meta.get("gen").unwrap();
    let cfg = GenCfg::from_json(gen_meta).unwrap();
    let n = entry.meta.get("n_chunks").unwrap().as_usize().unwrap();

    let seed = 42u64;
    let gen = Generator::from_seed(cfg.clone(), seed);
    let alpha = Stream::sub(seed, tag::ALPHA).normal_f32(n * cfg.k, 0.5);
    let beta = Stream::sub(seed, tag::COEF).uniform_f32(n, -1.5, 1.5);

    // positional inputs: alpha, beta, gw0, gw1, gw2
    let mut inputs = vec![
        Tensor::from_f32(alpha.clone(), &[n, cfg.k]).unwrap(),
        Tensor::from_f32(beta.clone(), &[n]).unwrap(),
    ];
    for (w, (a, b)) in gen.ws.iter().zip(cfg.layer_shapes()) {
        inputs.push(Tensor::from_f32(w.clone(), &[a, b]).unwrap());
    }
    let out = sess.run("gen_mlp02_fwd", &inputs).unwrap();
    let xla_out = out[0].f32s().unwrap();

    let native = gen.forward(&alpha, &beta);
    assert_eq!(xla_out.len(), native.len());
    let max_diff = xla_out
        .iter()
        .zip(&native)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "XLA vs native generator diverge: {max_diff}");
}

/// Init laws + train step: the mlp MCNC executable must learn on a
/// synthetic linearly-separable task, driven exactly like production.
#[test]
fn mcnc_train_step_learns_through_pjrt() {
    let Some(sess) = session() else { return };
    let name = "mlp_mcnc02_train";
    let entry = sess.entry(name).unwrap().clone();
    let seed = 7u64;
    let mut slots = init::init_inputs(&entry, seed).unwrap();

    let ns = entry.count_role(Role::Static);
    let nt = entry.count_role(Role::Trainable);
    let batch = 128usize;
    let in_dim = 784usize;

    // deterministic learnable task: y = argmax(x @ W_task)
    let wtask = Stream::new(99).normal_f32(in_dim * 10, 1.0);
    let make_batch = |step: u64| -> (Tensor, Tensor) {
        let x = Stream::sub(seed, tag::DATA + step).normal_f32(batch * in_dim, 1.0);
        let mut y = vec![0i32; batch];
        for b in 0..batch {
            let mut best = (f32::MIN, 0usize);
            for c in 0..10 {
                let mut s = 0.0f32;
                for i in 0..in_dim {
                    s += x[b * in_dim + i] * wtask[i * 10 + c];
                }
                if s > best.0 {
                    best = (s, c);
                }
            }
            y[b] = best.1 as i32;
        }
        (
            Tensor::from_f32(x, &[batch, in_dim]).unwrap(),
            Tensor::from_i32(y, &[batch]).unwrap(),
        )
    };

    let mut t = 0.0f32;
    let mut losses = Vec::new();
    for step in 0..30u64 {
        let (x, y) = make_batch(step % 4);
        let mut inputs: Vec<Tensor> = slots[..ns + 3 * nt]
            .iter()
            .map(|(_, t)| t.clone().unwrap())
            .collect();
        inputs.push(Tensor::scalar_f32(t));
        inputs.push(Tensor::scalar_f32(0.05));
        inputs.push(x);
        inputs.push(y);
        let out = sess.run(name, &inputs).unwrap();
        // outputs: trainables', m', v', t', loss, acc
        for i in 0..3 * nt {
            slots[ns + i].1 = Some(out[i].clone());
        }
        t = out[3 * nt].scalar().unwrap();
        losses.push(out[3 * nt + 1].scalar().unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    let first = losses[0];
    let last = losses[25..].iter().cloned().fold(f32::MAX, f32::min);
    assert!(
        last < first - 0.05,
        "PJRT mcnc training did not learn: {first} -> {last} ({losses:?})"
    );
    assert_eq!(t, 30.0);
}

/// Reconstruction at the zero init equals θ0 (the paper's zero-init
/// guarantee through the whole stack).
#[test]
fn recon_at_init_equals_theta0() {
    let Some(sess) = session() else { return };
    let name = "mlp_mcnc02_recon";
    let entry = sess.entry(name).unwrap().clone();
    let seed = 3u64;
    let slots = init::init_inputs(&entry, seed).unwrap();
    let inputs: Vec<Tensor> = slots.iter().map(|(_, t)| t.clone().unwrap()).collect();
    let theta0_idx = entry.input_index("theta0_c").unwrap();
    let out = sess.run(name, &inputs).unwrap();
    let diff = mcnc::tensor::max_abs_diff(&out[0], &inputs[theta0_idx]);
    assert!(diff < 1e-6, "Δθ at zero init is {diff}, want 0");
}

/// Eval executable agrees with the loss the train step reports.
#[test]
fn eval_matches_train_loss() {
    let Some(sess) = session() else { return };
    let train = sess.entry("mlp_mcnc02_train").unwrap().clone();
    let evale = sess.entry("mlp_mcnc02_eval").unwrap().clone();
    let seed = 11u64;
    let slots = init::init_inputs(&train, seed).unwrap();
    let ns = train.count_role(Role::Static);
    let nt = train.count_role(Role::Trainable);

    let batch = 128;
    let x = Tensor::from_f32(Stream::new(1).normal_f32(batch * 784, 1.0), &[batch, 784]).unwrap();
    let y = Tensor::from_i32(
        Stream::new(2).uniform_f32(batch, 0.0, 10.0).iter().map(|v| *v as i32).collect(),
        &[batch],
    )
    .unwrap();

    // train step with lr=0 reports the current loss and changes nothing
    let mut tin: Vec<Tensor> =
        slots[..ns + 3 * nt].iter().map(|(_, t)| t.clone().unwrap()).collect();
    tin.push(Tensor::scalar_f32(0.0));
    tin.push(Tensor::scalar_f32(0.0));
    tin.push(x.clone());
    tin.push(y.clone());
    let tout = sess.run("mlp_mcnc02_train", &tin).unwrap();
    let train_loss = tout[3 * nt + 1].scalar().unwrap();

    let mut ein: Vec<Tensor> =
        slots[..ns + nt].iter().map(|(_, t)| t.clone().unwrap()).collect();
    ein.push(x);
    ein.push(y);
    let eout = sess.run("mlp_mcnc02_eval", &ein).unwrap();
    let eval_loss = eout[0].scalar().unwrap();
    assert!(
        (train_loss - eval_loss).abs() < 1e-4,
        "train {train_loss} vs eval {eval_loss}"
    );
    assert_eq!(evale.outputs.len(), 2);
}
