//! End-to-end serving integration: submit real requests through the full
//! router → batcher → engine → PJRT predict path and check the invariants
//! the coordinator promises (every request answered exactly once, both
//! execution modes agree on predictions, adapters actually differ by task).

use std::time::Duration;

use mcnc::coordinator::workload::request_tokens;
use mcnc::coordinator::{BatchPolicy, Mode, Server, ServerCfg, ServeStats};
use mcnc::data::MarkovLm;
use mcnc::runtime::artifacts_dir;

fn ready() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

fn run_requests(cfg: ServerCfg, n: usize, n_tasks: usize) -> (Vec<(u64, usize, i32)>, ServeStats) {
    let lm = MarkovLm::base(1, 128, 32);
    let server = Server::start(artifacts_dir(), cfg);
    let mut rxs = Vec::new();
    for i in 0..n {
        let task = i % n_tasks;
        let tokens = request_tokens(&lm, 7, i as u64);
        rxs.push(server.submit(task, tokens));
    }
    let mut out = Vec::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
        out.push((resp.id, resp.task, resp.next_token));
    }
    let stats = server.stop().unwrap();
    (out, stats)
}

#[test]
fn serves_all_requests_exactly_once() {
    if !ready() {
        return;
    }
    let cfg = ServerCfg {
        kind: "lm_mcnclora8".into(),
        n_tasks: 4,
        policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(2) },
        mode: Mode::OnTheFly,
        ..ServerCfg::default()
    };
    let (resps, stats) = run_requests(cfg, 64, 4);
    assert_eq!(resps.len(), 64);
    let ids: std::collections::HashSet<u64> = resps.iter().map(|r| r.0).collect();
    assert_eq!(ids.len(), 64, "duplicate or dropped responses");
    assert!(stats.batches >= 4, "expected multiple batches, got {}", stats.batches);
    assert_eq!(stats.rows, stats.batches * 16);
    assert!(stats.recon_flops > 0);
    assert!(resps.iter().all(|r| (0..128).contains(&r.2)));
}

#[test]
fn predictions_deterministic_per_task() {
    if !ready() {
        return;
    }
    let mk = || ServerCfg {
        kind: "lm_mcnclora8".into(),
        n_tasks: 2,
        policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(1) },
        mode: Mode::OnTheFly,
        ..ServerCfg::default()
    };
    let (a, _) = run_requests(mk(), 32, 2);
    let (b, _) = run_requests(mk(), 32, 2);
    assert_eq!(a, b, "same workload + seed must give identical predictions");
}

#[test]
fn merged_mode_agrees_with_on_the_fly() {
    if !ready() {
        return;
    }
    let base = ServerCfg {
        kind: "lm_mcnclora8".into(),
        n_tasks: 2,
        policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(1) },
        mode: Mode::OnTheFly,
        ..ServerCfg::default()
    };
    let mut merged = base.clone();
    merged.mode = Mode::Merged;
    let (fly, fly_stats) = run_requests(base, 48, 2);
    let (mrg, mrg_stats) = run_requests(merged, 48, 2);
    // reconstruct-then-dense == in-graph reconstruction, bit-for-bit argmax
    assert_eq!(fly, mrg);
    assert!(mrg_stats.cache_hits > 0, "no cache hits in merged mode");
    assert!(
        mrg_stats.recon_flops < fly_stats.recon_flops,
        "merged mode should amortize reconstruction: {} vs {}",
        mrg_stats.recon_flops,
        fly_stats.recon_flops
    );
}

#[test]
fn merged_native_recon_fills_cold_tasks() {
    if !ready() {
        return;
    }
    // cold tasks filled by the native blocked-GEMM engine (no PJRT recon
    // dispatch); warm traffic must hit the cache exactly as before
    let base = ServerCfg {
        kind: "lm_mcnclora8".into(),
        n_tasks: 2,
        policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(1) },
        mode: Mode::OnTheFly,
        ..ServerCfg::default()
    };
    let mut native = base.clone();
    native.mode = Mode::Merged;
    native.native_recon = true;
    let (fly, _) = run_requests(base, 48, 2);
    let (resps, stats) = run_requests(native, 48, 2);
    assert_eq!(resps.len(), 48);
    assert_eq!(
        stats.native_fills, stats.cache_misses,
        "every cold fill should be native for an mcnc_lora kind"
    );
    assert!(stats.native_fills >= 2, "both tasks start cold");
    assert!(stats.cache_hits > 0);
    assert!(resps.iter().all(|r| (0..128).contains(&r.2)));
    // native θ differs from the in-graph reconstruction only by f32
    // summation order (ulps), so argmaxes must agree except on rare
    // near-ties; a wrong LoRA assembly would drop agreement to ~1/|V|
    let agree = fly.iter().zip(&resps).filter(|(a, b)| a.2 == b.2).count();
    assert!(
        agree * 10 >= resps.len() * 9,
        "native recon diverges from OnTheFly: {agree}/{} agree",
        resps.len()
    );
}

#[test]
fn different_adapters_give_different_predictions() {
    if !ready() {
        return;
    }
    let lm = MarkovLm::base(1, 128, 32);
    let cfg = ServerCfg {
        kind: "lm_mcnclora8".into(),
        n_tasks: 2,
        policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(1) },
        mode: Mode::OnTheFly,
        ..ServerCfg::default()
    };
    let server = Server::start(artifacts_dir(), cfg);
    let mut pairs = Vec::new();
    for i in 0..16u64 {
        let tokens = request_tokens(&lm, 3, i);
        let r0 = server.submit(0, tokens.clone());
        let r1 = server.submit(1, tokens);
        pairs.push((r0, r1));
    }
    let mut diffs = 0;
    for (r0, r1) in pairs {
        let a = r0.recv_timeout(Duration::from_secs(120)).unwrap();
        let b = r1.recv_timeout(Duration::from_secs(120)).unwrap();
        if a.next_token != b.next_token {
            diffs += 1;
        }
    }
    server.stop().unwrap();
    assert!(diffs > 0, "task adapters appear identical");
}
