//! End-to-end serving integration, in two tiers:
//!
//! * **Coordinator tests (always run)** — a mock engine plugged into
//!   `Server::start_with` exercises the sharded dispatcher itself: task
//!   affinity, per-request fault isolation, admission-control
//!   backpressure, idle heartbeat behaviour and per-shard stats merging.
//!   No PJRT artifacts needed.
//! * **Engine tests (artifact-gated)** — real requests through the full
//!   router → batcher → PJRT predict path, checking the invariants the
//!   coordinator promises (every request answered exactly once, both
//!   execution modes agree on predictions, sharding preserves
//!   predictions, a malformed request never takes a shard down).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;
use mcnc::coordinator::workload::request_tokens;
use mcnc::coordinator::{
    Batch, BatchPolicy, EngineCore, Mode, Response, ServeError, ServeStats, Server, ServerCfg,
};
use mcnc::data::MarkovLm;
use mcnc::runtime::artifacts_dir;
use mcnc::util::prop::run_prop;
use mcnc::prop_assert;

// ---------------------------------------------------------------------------
// Mock-engine coordinator tests (no artifacts required)
// ---------------------------------------------------------------------------

/// Deterministic stand-in engine: predicts `shard * 1000 + task` so tests
/// can verify which shard served a request. Optional failure injection and
/// a gate the test can hold shut to keep the shard busy mid-batch.
struct MockEngine {
    shard: usize,
    n_tasks: usize,
    seq: usize,
    batch_size: usize,
    fail_task: Option<usize>,
    gate: Option<Arc<Mutex<()>>>,
    entered: Arc<AtomicUsize>,
    stats: ServeStats,
}

#[derive(Clone)]
struct MockCfg {
    n_tasks: usize,
    seq: usize,
    batch_size: usize,
    fail_task: Option<usize>,
    gate: Option<Arc<Mutex<()>>>,
    entered: Arc<AtomicUsize>,
}

impl MockCfg {
    fn new(n_tasks: usize, seq: usize, batch_size: usize) -> MockCfg {
        MockCfg {
            n_tasks,
            seq,
            batch_size,
            fail_task: None,
            gate: None,
            entered: Arc::new(AtomicUsize::new(0)),
        }
    }

    fn server(&self, cfg: &ServerCfg) -> Server {
        let mock = self.clone();
        Server::start_with(cfg, move |shard| -> Result<MockEngine> {
            Ok(MockEngine {
                shard,
                n_tasks: mock.n_tasks,
                seq: mock.seq,
                batch_size: mock.batch_size,
                fail_task: mock.fail_task,
                gate: mock.gate.clone(),
                entered: Arc::clone(&mock.entered),
                stats: ServeStats::default(),
            })
        })
        .expect("start mock server")
    }
}

impl EngineCore for MockEngine {
    fn seq(&self) -> usize {
        self.seq
    }

    fn has_task(&self, task: usize) -> bool {
        task < self.n_tasks
    }

    fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        if let Some(gate) = &self.gate {
            drop(gate.lock().unwrap());
        }
        if self.fail_task == Some(batch.task) {
            anyhow::bail!("injected failure for task {}", batch.task);
        }
        self.stats.batches += 1;
        self.stats.rows += self.batch_size as u64;
        self.stats.padded_rows += (self.batch_size - batch.requests.len()) as u64;
        Ok(batch
            .requests
            .iter()
            .map(|r| (self.shard * 1000 + r.task) as i32)
            .collect())
    }

    fn stats_mut(&mut self) -> &mut ServeStats {
        &mut self.stats
    }

    fn into_stats(self) -> ServeStats {
        self.stats
    }
}

fn mock_server_cfg(n_shards: usize, max_batch: usize) -> ServerCfg {
    ServerCfg {
        n_shards,
        policy: BatchPolicy { max_batch, max_delay: Duration::from_millis(1) },
        heartbeat: Duration::from_millis(10),
        ..ServerCfg::default()
    }
}

fn recv(rx: std::sync::mpsc::Receiver<Response>) -> Response {
    rx.recv_timeout(Duration::from_secs(30)).expect("response")
}

#[test]
fn mock_malformed_request_isolated_then_valid_completes() {
    let mock = MockCfg::new(8, 8, 4);
    let server = mock.server(&mock_server_cfg(4, 4));
    // regression: a malformed request (wrong token count) must produce an
    // error Response for itself only — the shard keeps serving
    let bad = server.submit(1, vec![0; 3]);
    let unknown = server.submit(99, vec![0; 8]); // 99 >= n_tasks, valid length
    let good = server.submit(1, vec![0; 8]);
    let r_bad = recv(bad);
    assert!(matches!(r_bad.result, Err(ServeError::Failed(_))), "{:?}", r_bad.result);
    let r_unknown = recv(unknown);
    assert!(matches!(r_unknown.result, Err(ServeError::Failed(_))), "{:?}", r_unknown.result);
    let r_good = recv(good);
    assert_eq!(r_good.next_token(), Some(1001), "shard 1 owns task 1");
    let stats = server.stop().unwrap();
    assert_eq!(stats.errors, 2);
    assert_eq!(stats.latency.count(), 1, "only the valid request completed");
    assert_eq!(stats.rejected, 0);
}

#[test]
fn mock_batch_failure_does_not_kill_the_shard() {
    let mut mock = MockCfg::new(8, 8, 4);
    mock.fail_task = Some(2);
    let server = mock.server(&mock_server_cfg(2, 4));
    // tasks 2 (failing, shard 0) and 1/3 (healthy, shard 1), interleaved
    let mut rxs = Vec::new();
    for i in 0..24 {
        rxs.push(server.submit(1 + (i % 3), vec![0; 8]));
    }
    let mut failed = 0;
    let mut ok = 0;
    for rx in rxs {
        let r = recv(rx);
        match &r.result {
            Ok(tok) => {
                ok += 1;
                assert_eq!(*tok, (1000 * (r.task % 2) + r.task) as i32);
            }
            Err(ServeError::Failed(m)) => {
                failed += 1;
                assert_eq!(r.task, 2, "only task 2 batches fail");
                assert!(m.contains("injected failure"), "{m}");
            }
            Err(e) => panic!("unexpected {e:?}"),
        }
    }
    assert_eq!(failed, 8);
    assert_eq!(ok, 16);
    // the shard that owned the failing task still serves: task 0 → shard 0
    let late = recv(server.submit(0, vec![0; 8]));
    assert_eq!(late.next_token(), Some(0));
    let stats = server.stop().unwrap();
    assert_eq!(stats.errors, 8);
    assert_eq!(stats.latency.count(), 17);
}

#[test]
fn mock_backpressure_rejects_when_admission_queue_full() {
    let gate = Arc::new(Mutex::new(()));
    let mut mock = MockCfg::new(4, 8, 1);
    mock.gate = Some(Arc::clone(&gate));
    let cfg = ServerCfg {
        n_shards: 1,
        queue_cap: 2,
        policy: BatchPolicy { max_batch: 1, max_delay: Duration::ZERO },
        heartbeat: Duration::from_millis(10),
        ..ServerCfg::default()
    };
    let server = mock.server(&cfg);

    // hold the gate shut, then park the shard inside run_batch
    let guard = gate.lock().unwrap();
    let first = server.submit(0, vec![0; 8]);
    let t0 = std::time::Instant::now();
    while mock.entered.load(Ordering::SeqCst) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "shard never started the batch");
        std::thread::sleep(Duration::from_millis(1));
    }
    // the shard is now blocked mid-batch: the admission queue (cap 2) must
    // overflow deterministically
    let mut rxs = Vec::new();
    for _ in 0..40 {
        rxs.push(server.submit(0, vec![0; 8]));
    }
    drop(guard);

    let mut ok = 1; // the parked request
    let mut rejected = 0;
    assert!(recv(first).is_ok());
    for rx in rxs {
        let r = recv(rx);
        match &r.result {
            Ok(_) => ok += 1,
            Err(ServeError::Rejected(_)) => rejected += 1,
            Err(e) => panic!("unexpected {e:?}"),
        }
    }
    assert_eq!(ok, 3, "exactly the parked request + queue_cap complete");
    assert_eq!(rejected, 38);
    let stats = server.stop().unwrap();
    assert_eq!(stats.rejected, 38, "dispatcher folds rejects into merged stats");
    assert_eq!(stats.latency.count(), 3);
    assert_eq!(stats.errors, 0);
}

#[test]
fn mock_idle_server_heartbeats_instead_of_spinning() {
    let mock = MockCfg::new(4, 8, 4);
    let cfg = ServerCfg {
        n_shards: 1,
        heartbeat: Duration::from_millis(50),
        ..ServerCfg::default()
    };
    let server = mock.server(&cfg);
    std::thread::sleep(Duration::from_millis(500));
    let stats = server.stop().unwrap();
    // the seed engine woke every 200µs (~2500 iterations in 500ms); the
    // shard loop must block on the heartbeat instead
    assert!(
        stats.wakeups <= 40,
        "idle loop iterated {} times in 500ms — busy-waiting",
        stats.wakeups
    );
    assert!(stats.wakeups >= 2, "loop never woke at all");
    assert_eq!(stats.batches, 0);
}

#[test]
fn mock_shard_affinity_and_exactly_once_property() {
    run_prop("shard_affinity", 20, |g| {
        let n_shards = g.usize(1, 4);
        let n_tasks = g.usize(1, 8);
        let nreq = g.usize(1, 40);
        let max_batch = g.usize(1, 8);
        let mock = MockCfg::new(n_tasks, 8, max_batch);
        let server = mock.server(&mock_server_cfg(n_shards, max_batch));
        let mut rxs = Vec::new();
        for i in 0..nreq {
            rxs.push((i % n_tasks, server.submit(i % n_tasks, vec![0; 8])));
        }
        let mut ids = std::collections::HashSet::new();
        for (task, rx) in rxs {
            let r = rx
                .recv_timeout(Duration::from_secs(30))
                .map_err(|e| format!("no response: {e}"))?;
            prop_assert!(r.task == task, "response for task {} on task {task}", r.task);
            let tok = match r.result {
                Ok(t) => t,
                Err(e) => return Err(format!("unexpected error: {e}")),
            };
            // the prediction encodes the serving shard: must be the owner
            let owner = (task % n_shards) as i32;
            prop_assert!(
                tok == owner * 1000 + task as i32,
                "task {task} served by shard {} not {owner}",
                tok / 1000
            );
            prop_assert!(ids.insert(r.id), "request {} answered twice", r.id);
        }
        prop_assert!(ids.len() == nreq, "answered {} of {nreq}", ids.len());
        let stats = server.stop().map_err(|e| e.to_string())?;
        // per-shard stats merge to exactly the submitted totals
        prop_assert!(
            stats.latency.count() == nreq as u64,
            "latency count {} != {nreq}",
            stats.latency.count()
        );
        prop_assert!(
            stats.queue_wait.count() == nreq as u64,
            "queue_wait count {} != {nreq}",
            stats.queue_wait.count()
        );
        prop_assert!(
            stats.rows - stats.padded_rows == nreq as u64,
            "rows {} padded {} != {nreq}",
            stats.rows,
            stats.padded_rows
        );
        prop_assert!(stats.errors == 0 && stats.rejected == 0, "spurious errors/rejects");
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Warm-start (preload) coordinator tests (no artifacts required)
// ---------------------------------------------------------------------------

/// Mock recording which shards were asked to preload; optional failure
/// injection on one shard.
struct WarmMock {
    shard: usize,
    log: Arc<Mutex<Vec<(usize, std::path::PathBuf)>>>,
    fail_shard: Option<usize>,
    stats: ServeStats,
}

impl EngineCore for WarmMock {
    fn seq(&self) -> usize {
        8
    }

    fn has_task(&self, _task: usize) -> bool {
        true
    }

    fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>> {
        Ok(batch.requests.iter().map(|_| 0).collect())
    }

    fn stats_mut(&mut self) -> &mut ServeStats {
        &mut self.stats
    }

    fn into_stats(self) -> ServeStats {
        self.stats
    }

    fn preload(&mut self, artifact: &std::path::Path) -> Result<mcnc::coordinator::WarmStats> {
        if self.fail_shard == Some(self.shard) {
            anyhow::bail!("injected preload failure");
        }
        self.log.lock().unwrap().push((self.shard, artifact.to_path_buf()));
        Ok(mcnc::coordinator::WarmStats { installed: 1, prefilled: 1, skipped: 2, quantized: 0 })
    }
}

fn warm_server(
    n_shards: usize,
    fail_shard: Option<usize>,
) -> (Server, Arc<Mutex<Vec<(usize, std::path::PathBuf)>>>) {
    let log = Arc::new(Mutex::new(Vec::new()));
    let l = Arc::clone(&log);
    let cfg = mock_server_cfg(n_shards, 4);
    let server = Server::start_with(&cfg, move |shard| -> Result<WarmMock> {
        Ok(WarmMock { shard, log: Arc::clone(&l), fail_shard, stats: ServeStats::default() })
    })
    .expect("start warm mock server");
    (server, log)
}

#[test]
fn preload_broadcasts_to_every_shard_and_sums_stats() {
    let (server, log) = warm_server(4, None);
    let warm = server.preload(std::path::Path::new("warm.mcnc2")).unwrap();
    // every shard acked with (1 installed, 1 prefilled, 2 skipped)
    assert_eq!(warm.installed, 4);
    assert_eq!(warm.prefilled, 4);
    assert_eq!(warm.skipped, 8);
    let mut shards: Vec<usize> = log.lock().unwrap().iter().map(|(s, _)| *s).collect();
    shards.sort_unstable();
    assert_eq!(shards, vec![0, 1, 2, 3], "each shard preloads exactly once");
    assert!(log.lock().unwrap().iter().all(|(_, p)| p.ends_with("warm.mcnc2")));
    // the server still serves after a preload
    let r = recv(server.submit(0, vec![0; 8]));
    assert!(r.is_ok(), "{:?}", r.result);
    server.stop().unwrap();
}

#[test]
fn preload_failure_names_the_shard_and_leaves_the_server_serving() {
    let (server, _log) = warm_server(3, Some(1));
    let err = server.preload(std::path::Path::new("warm.mcnc2")).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("shard 1"), "{msg}");
    assert!(msg.contains("injected preload failure"), "{msg}");
    // a failed preload must not take shards down
    for task in 0..3 {
        let r = recv(server.submit(task, vec![0; 8]));
        assert!(r.is_ok(), "{:?}", r.result);
    }
    server.stop().unwrap();
}

#[test]
fn default_enginecore_preload_is_a_noop() {
    // MockEngine doesn't override preload: the trait default reports zero
    // work and the coordinator path still completes
    let mock = MockCfg::new(4, 8, 4);
    let server = mock.server(&mock_server_cfg(2, 4));
    let warm = server.preload(std::path::Path::new("ignored")).unwrap();
    assert_eq!(warm, mcnc::coordinator::WarmStats::default());
    server.stop().unwrap();
}

// ---------------------------------------------------------------------------
// PJRT-backed engine tests (skip when artifacts are absent)
// ---------------------------------------------------------------------------

fn ready() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

fn run_requests(cfg: ServerCfg, n: usize, n_tasks: usize) -> (Vec<(u64, usize, i32)>, ServeStats) {
    let lm = MarkovLm::base(1, 128, 32);
    let server = Server::start(artifacts_dir(), cfg).expect("start server");
    let mut rxs = Vec::new();
    for i in 0..n {
        let task = i % n_tasks;
        let tokens = request_tokens(&lm, 7, i as u64);
        rxs.push(server.submit(task, tokens));
    }
    let mut out = Vec::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
        let tok = resp.next_token().unwrap_or_else(|| panic!("error response: {:?}", resp.result));
        out.push((resp.id, resp.task, tok));
    }
    let stats = server.stop().unwrap();
    (out, stats)
}

#[test]
fn serves_all_requests_exactly_once() {
    if !ready() {
        return;
    }
    let cfg = ServerCfg {
        kind: "lm_mcnclora8".into(),
        n_tasks: 4,
        policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(2) },
        mode: Mode::OnTheFly,
        ..ServerCfg::default()
    };
    let (resps, stats) = run_requests(cfg, 64, 4);
    assert_eq!(resps.len(), 64);
    let ids: std::collections::HashSet<u64> = resps.iter().map(|r| r.0).collect();
    assert_eq!(ids.len(), 64, "duplicate or dropped responses");
    assert!(stats.batches >= 4, "expected multiple batches, got {}", stats.batches);
    assert_eq!(stats.rows, stats.batches * 16);
    assert_eq!(stats.queue_wait.count(), 64, "queue wait recorded per dispatched request");
    assert!(stats.recon_flops > 0);
    assert!(resps.iter().all(|r| (0..128).contains(&r.2)));
}

#[test]
fn predictions_deterministic_per_task() {
    if !ready() {
        return;
    }
    let mk = || ServerCfg {
        kind: "lm_mcnclora8".into(),
        n_tasks: 2,
        policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(1) },
        mode: Mode::OnTheFly,
        ..ServerCfg::default()
    };
    let (a, _) = run_requests(mk(), 32, 2);
    let (b, _) = run_requests(mk(), 32, 2);
    assert_eq!(a, b, "same workload + seed must give identical predictions");
}

#[test]
fn sharding_preserves_predictions() {
    if !ready() {
        return;
    }
    // task t is seeded identically regardless of which shard owns it, so a
    // 4-shard server must predict exactly what the single engine predicts
    let mk = |n_shards| ServerCfg {
        kind: "lm_mcnclora8".into(),
        n_tasks: 4,
        n_shards,
        policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(1) },
        mode: Mode::OnTheFly,
        ..ServerCfg::default()
    };
    let (one, _) = run_requests(mk(1), 32, 4);
    let (four, stats) = run_requests(mk(4), 32, 4);
    assert_eq!(one, four, "sharding changed predictions");
    assert_eq!(stats.latency.count(), 32);
}

#[test]
fn fault_isolation_on_4shard_server() {
    if !ready() {
        return;
    }
    // the acceptance scenario: malformed + unknown-task requests yield
    // error Responses while concurrent valid traffic on all shards
    // completes, and per-shard stats merge to the submitted totals
    let lm = MarkovLm::base(1, 128, 32);
    let cfg = ServerCfg {
        kind: "lm_mcnclora8".into(),
        n_tasks: 4,
        n_shards: 4,
        policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(1) },
        mode: Mode::Merged,
        native_recon: true,
        ..ServerCfg::default()
    };
    let server = Server::start(artifacts_dir(), cfg).expect("start server");
    let wrong_len = server.submit(0, vec![1, 2, 3]);
    let unknown = server.submit(100, request_tokens(&lm, 7, 0));
    let mut valid = Vec::new();
    for i in 0..32u64 {
        valid.push(server.submit((i % 4) as usize, request_tokens(&lm, 7, i)));
    }
    let r = wrong_len.recv_timeout(Duration::from_secs(120)).unwrap();
    assert!(matches!(r.result, Err(ServeError::Failed(_))), "{:?}", r.result);
    let r = unknown.recv_timeout(Duration::from_secs(120)).unwrap();
    assert!(matches!(r.result, Err(ServeError::Failed(_))), "{:?}", r.result);
    for rx in valid {
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(r.is_ok(), "valid request failed: {:?}", r.result);
    }
    let stats = server.stop().unwrap();
    assert_eq!(stats.errors, 2);
    assert_eq!(stats.latency.count(), 32, "one latency sample per valid request");
    assert_eq!(stats.queue_wait.count(), 32);
    assert_eq!(
        stats.cache_hits + stats.cache_misses,
        stats.batches,
        "every merged batch is a hit or a miss"
    );
    assert!(stats.cache_misses >= 4, "each shard's task starts cold");
}

#[test]
fn merged_mode_agrees_with_on_the_fly() {
    if !ready() {
        return;
    }
    let base = ServerCfg {
        kind: "lm_mcnclora8".into(),
        n_tasks: 2,
        policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(1) },
        mode: Mode::OnTheFly,
        ..ServerCfg::default()
    };
    let mut merged = base.clone();
    merged.mode = Mode::Merged;
    let (fly, fly_stats) = run_requests(base, 48, 2);
    let (mrg, mrg_stats) = run_requests(merged, 48, 2);
    // reconstruct-then-dense == in-graph reconstruction, bit-for-bit argmax
    assert_eq!(fly, mrg);
    assert!(mrg_stats.cache_hits > 0, "no cache hits in merged mode");
    assert!(
        mrg_stats.recon_flops < fly_stats.recon_flops,
        "merged mode should amortize reconstruction: {} vs {}",
        mrg_stats.recon_flops,
        fly_stats.recon_flops
    );
}

#[test]
fn merged_native_recon_fills_cold_tasks() {
    if !ready() {
        return;
    }
    // cold tasks filled by the native blocked-GEMM engine (no PJRT recon
    // dispatch); warm traffic must hit the cache exactly as before
    let base = ServerCfg {
        kind: "lm_mcnclora8".into(),
        n_tasks: 2,
        policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(1) },
        mode: Mode::OnTheFly,
        ..ServerCfg::default()
    };
    let mut native = base.clone();
    native.mode = Mode::Merged;
    native.native_recon = true;
    let (fly, _) = run_requests(base, 48, 2);
    let (resps, stats) = run_requests(native, 48, 2);
    assert_eq!(resps.len(), 48);
    assert_eq!(
        stats.native_fills, stats.cache_misses,
        "every cold fill should be native for an mcnc_lora kind"
    );
    assert!(stats.native_fills >= 2, "both tasks start cold");
    assert!(stats.cache_hits > 0);
    assert!(resps.iter().all(|r| (0..128).contains(&r.2)));
    // native θ differs from the in-graph reconstruction only by f32
    // summation order (ulps), so argmaxes must agree except on rare
    // near-ties; a wrong LoRA assembly would drop agreement to ~1/|V|
    let agree = fly.iter().zip(&resps).filter(|(a, b)| a.2 == b.2).count();
    assert!(
        agree * 10 >= resps.len() * 9,
        "native recon diverges from OnTheFly: {agree}/{} agree",
        resps.len()
    );
}

#[test]
fn preload_prefills_merged_cache_and_preserves_predictions() {
    if !ready() {
        return;
    }
    // the acceptance scenario for warm starts: a lossless warm artifact
    // written from the same base seed installs bit-identical adapters and
    // pre-reconstructs every task's θ, so Merged traffic never cold-fills
    let dir = std::env::temp_dir().join(format!("mcnc_warm_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let artifact = dir.join("warm.mcnc2");
    let wire = mcnc::coordinator::warm::write_synth_artifact(
        &artifacts_dir(),
        &artifact,
        "lm_mcnclora8",
        2,
        1,
        mcnc::codec::Codec::Lossless,
    )
    .unwrap();
    assert_eq!(wire as u64, std::fs::metadata(&artifact).unwrap().len());

    let mk = || ServerCfg {
        kind: "lm_mcnclora8".into(),
        n_tasks: 2,
        n_shards: 2,
        policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(1) },
        mode: Mode::Merged,
        native_recon: true,
        ..ServerCfg::default()
    };

    // cold server: first batch per task is a native cold fill
    let (cold_resps, cold_stats) = run_requests(mk(), 32, 2);
    assert!(cold_stats.cache_misses >= 2);

    // warm server: preload, then identical traffic — zero cold fills
    let lm = MarkovLm::base(1, 128, 32);
    let server = Server::start(artifacts_dir(), mk()).expect("start server");
    let warm = server.preload(&artifact).unwrap();
    assert_eq!(warm.installed, 2, "one adapter per task");
    assert_eq!(warm.prefilled, 2, "every task's θ pre-reconstructed");
    // each shard skips the other shard's task frames (count depends on the
    // family's trainable slot count, so only the shape is asserted)
    assert!(warm.skipped > 0 && warm.skipped % 2 == 0, "skipped {}", warm.skipped);
    let mut rxs = Vec::new();
    for i in 0..32 {
        rxs.push(server.submit(i % 2, request_tokens(&lm, 7, i as u64)));
    }
    let mut warm_resps = Vec::new();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        let tok = r.next_token().unwrap_or_else(|| panic!("error response: {:?}", r.result));
        warm_resps.push((r.id, r.task, tok));
    }
    let stats = server.stop().unwrap();
    assert_eq!(stats.cache_misses, 0, "warm start leaves no cold fills");
    assert_eq!(stats.native_fills, 0, "no request-path reconstructions");
    assert!(stats.cache_hits > 0);
    // lossless warm artifact from the same seed == the self-seeded
    // adapters, so predictions must match the cold server's exactly
    assert_eq!(cold_resps, warm_resps, "preload changed predictions");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn different_adapters_give_different_predictions() {
    if !ready() {
        return;
    }
    let lm = MarkovLm::base(1, 128, 32);
    let cfg = ServerCfg {
        kind: "lm_mcnclora8".into(),
        n_tasks: 2,
        policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(1) },
        mode: Mode::OnTheFly,
        ..ServerCfg::default()
    };
    let server = Server::start(artifacts_dir(), cfg).expect("start server");
    let mut pairs = Vec::new();
    for i in 0..16u64 {
        let tokens = request_tokens(&lm, 3, i);
        let r0 = server.submit(0, tokens.clone());
        let r1 = server.submit(1, tokens);
        pairs.push((r0, r1));
    }
    let mut diffs = 0;
    for (r0, r1) in pairs {
        let a = r0.recv_timeout(Duration::from_secs(120)).unwrap();
        let b = r1.recv_timeout(Duration::from_secs(120)).unwrap();
        if a.next_token() != b.next_token() {
            diffs += 1;
        }
    }
    server.stop().unwrap();
    assert!(diffs > 0, "task adapters appear identical");
}
