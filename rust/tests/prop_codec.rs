//! Randomized properties of the MCNC2 codec subsystem: lossless mode is
//! bit-exact for arbitrary f32 bit patterns (NaNs, infinities, denormals
//! included), quantized modes reproduce `fake_quant` exactly and stay
//! within the absmax error bound, the rANS coder round-trips any symbol
//! stream, and corrupted containers — truncations and single-bit flips
//! anywhere in the stream — always fail with an error: never a panic,
//! never a silent mis-decode.

use mcnc::codec::{container, quantizer, rans, Codec, ContainerHeader, Decoder, Encoder};
use mcnc::mcnc::kernel::{self, Isa};
use mcnc::prop_assert;
use mcnc::tensor::Tensor;
use mcnc::train::Checkpoint;
use mcnc::util::prop::{run_prop, Gen};

/// anyhow → property-error adapter.
fn e<T>(r: anyhow::Result<T>) -> Result<T, String> {
    r.map_err(|x| format!("{x:#}"))
}

/// Fully decode a container, counting tensors.
fn drain(bytes: &[u8]) -> anyhow::Result<usize> {
    let mut dec = Decoder::new(bytes)?;
    let mut n = 0;
    while let Some(_frame) = dec.next_tensor()? {
        n += 1;
    }
    Ok(n)
}

/// A random multi-tensor container (random shapes, values, codecs) that is
/// checked to decode cleanly before being returned.
fn random_container(g: &mut Gen) -> Result<Vec<u8>, String> {
    let n_t = g.usize(1, 4);
    let mut tensors = Vec::new();
    for i in 0..n_t {
        let rows = g.usize(1, 12);
        let cols = g.usize(1, 12);
        let vals = g.vec_f32(rows * cols, -1.0, 1.0);
        tensors.push((format!("t{i}"), Tensor::from_f32(vals, &[rows, cols]).unwrap()));
    }
    let header =
        ContainerHeader { entry: "prop".into(), seed: 7, step: 0.0, n_tensors: Some(n_t) };
    let mut enc = e(Encoder::new(Vec::new(), &header))?;
    for (name, t) in &tensors {
        let codec = *g.pick(&[Codec::Lossless, Codec::Int8 { block: 16 }, Codec::Int4 { block: 8 }]);
        e(enc.write_tensor(name, t, codec))?;
    }
    let (bytes, _total) = e(enc.finish())?;
    match drain(&bytes) {
        Ok(n) if n == n_t => Ok(bytes),
        Ok(n) => Err(format!("pristine container decoded {n} of {n_t} tensors")),
        Err(err) => Err(format!("pristine container failed to decode: {err:#}")),
    }
}

#[test]
fn rans_roundtrips_any_stream() {
    run_prop("rans_roundtrip", 120, |g| {
        let bits = *g.pick(&[1usize, 4, 8]);
        let alphabet = 1usize << bits;
        let n = g.usize(0, 1500);
        let skew = g.bool();
        let mut syms = Vec::with_capacity(n);
        for _ in 0..n {
            let a = g.usize(0, alphabet - 1);
            let b = g.usize(0, alphabet - 1);
            syms.push(if skew { a.min(b) } else { a } as u8);
        }
        let blob = rans::encode(&syms, alphabet);
        let back = e(rans::decode(&blob, n, alphabet))?;
        prop_assert!(back == syms, "rans roundtrip mismatch (n={n}, alphabet={alphabet})");
        Ok(())
    });
}

#[test]
fn lossless_roundtrip_is_bit_exact() {
    run_prop("codec_lossless_bits", 60, |g| {
        let n = g.usize(0, 600);
        let vals: Vec<f32> = (0..n)
            .map(|_| {
                if g.bool() {
                    // arbitrary bit patterns: NaNs, ±inf, denormals, -0.0
                    f32::from_bits(g.usize(0, u32::MAX as usize) as u32)
                } else {
                    g.f32(-2.0, 2.0)
                }
            })
            .collect();
        let t = Tensor::from_f32(vals.clone(), &[n]).unwrap();
        let seed = ((g.usize(0, u32::MAX as usize) as u64) << 32)
            | g.usize(0, u32::MAX as usize) as u64;
        let header =
            ContainerHeader { entry: "p".into(), seed, step: 1.0, n_tensors: Some(1) };
        let mut enc = e(Encoder::new(Vec::new(), &header))?;
        e(enc.write_tensor("w", &t, Codec::Lossless))?;
        let (bytes, total) = e(enc.finish())?;
        prop_assert!(bytes.len() == total, "wire accounting drifted");

        let mut dec = e(Decoder::new(&bytes[..]))?;
        prop_assert!(dec.header().seed == seed, "seed drifted through the header");
        let (name, back, codec) =
            e(dec.next_tensor())?.ok_or_else(|| "no tensor decoded".to_string())?;
        prop_assert!(name == "w", "name drifted: {name:?}");
        prop_assert!(codec == Codec::Lossless, "codec tag drifted");
        let bw = back.f32s().unwrap();
        for (i, (a, b)) in vals.iter().zip(bw).enumerate() {
            prop_assert!(
                a.to_bits() == b.to_bits(),
                "bit drift at {i}: {:#010x} vs {:#010x}",
                a.to_bits(),
                b.to_bits()
            );
        }
        prop_assert!(e(dec.next_tensor())?.is_none(), "phantom extra tensor");
        Ok(())
    });
}

#[test]
fn quantized_roundtrip_matches_fake_quant_and_bound() {
    run_prop("codec_quant_bound", 60, |g| {
        let n = g.usize(0, 800);
        let block = g.usize(1, 128);
        let codec = if g.bool() { Codec::Int8 { block } } else { Codec::Int4 { block } };
        let bits = if matches!(codec, Codec::Int8 { .. }) { 8u32 } else { 4 };
        let vals = g.vec_f32(n, -3.0, 3.0);
        let t = Tensor::from_f32(vals.clone(), &[n]).unwrap();

        let body = e(container::encode_frame("q", &t, codec))?;
        let (_, back, c) = e(container::decode_frame(&body))?;
        prop_assert!(c == codec, "codec tag drifted");
        let bw = back.f32s().unwrap();
        prop_assert!(bw.len() == n, "length drifted");

        // exact agreement with the fake-quant simulation…
        let mut expect = vals.clone();
        mcnc::baselines::quant::fake_quant(&mut expect, bits, block);
        for i in 0..n {
            prop_assert!(
                bw[i] == expect[i],
                "bits={bits} block={block} [{i}]: {:e} vs fake_quant {:e}",
                bw[i],
                expect[i]
            );
        }
        // …and within the absmax bound per block
        let bound = mcnc::baselines::quant::worst_rel_error(bits) * 1.01;
        for (orig, dq) in vals.chunks(block).zip(bw.chunks(block)) {
            let absmax = orig.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            for (a, b) in orig.iter().zip(dq) {
                prop_assert!(
                    (a - b).abs() <= absmax * bound,
                    "error {:e} above bound {:e}",
                    (a - b).abs(),
                    absmax * bound
                );
            }
        }
        Ok(())
    });
}

#[test]
fn truncated_streams_always_error() {
    run_prop("codec_truncation", 40, |g| {
        let bytes = random_container(g)?;
        let cut = g.usize(0, bytes.len() - 1);
        match drain(&bytes[..cut]) {
            Err(_) => Ok(()),
            Ok(n) => Err(format!("prefix {cut}/{} decoded cleanly ({n} tensors)", bytes.len())),
        }
    });
}

#[test]
fn bit_flipped_streams_always_error() {
    run_prop("codec_bitflip", 60, |g| {
        let bytes = random_container(g)?;
        let ix = g.usize(0, bytes.len() - 1);
        let bit = g.usize(0, 7);
        let mut bad = bytes;
        bad[ix] ^= 1 << bit;
        match drain(&bad) {
            Err(_) => Ok(()),
            Ok(_) => Err(format!("bit flip at byte {ix} bit {bit} decoded cleanly")),
        }
    });
}

#[test]
fn fused_packed_q_decode_equals_quantize_then_pack() {
    // the compressed-domain decode (wire symbols → i8 panels, no f32
    // materialization) must build bit-for-bit the same PackedBQ as the
    // two-step reference: quantize the SOURCE weight (what the wire
    // embeds, ISA-invariantly) and pack the result — on every ISA
    run_prop("codec_packed_q_parity", 40, |g| {
        let k = g.usize(1, 20);
        let n = g.usize(1, 16);
        let block = *g.pick(&[n, 2 * n, k * n]);
        let codec = if g.bool() { Codec::Int8 { block } } else { Codec::Int4 { block } };
        let bits = if matches!(codec, Codec::Int8 { .. }) { 8u32 } else { 4 };
        let vals = g.vec_f32(k * n, -2.0, 2.0);
        let t = Tensor::from_f32(vals.clone(), &[k, n]).unwrap();
        let body = e(container::encode_frame("w", &t, codec))?;
        let q = quantizer::quantize_with(Isa::Scalar, &vals, bits, block);
        for isa in [Isa::Scalar, kernel::active()] {
            let (name, pq, c) = e(container::decode_frame_into_packed_q(&body, isa))?;
            prop_assert!(name == "w" && c == codec, "meta drifted ({isa:?})");
            let want = e(kernel::pack_bq_for(isa, k, n, bits, block, &q.scales, &q.symbols))?;
            prop_assert!(
                pq.isa() == want.isa()
                    && pq.ku() == want.ku()
                    && pq.bits() == want.bits()
                    && pq.group_rows() == want.group_rows(),
                "({k},{n}) block={block} {isa:?}: layout metadata drifted"
            );
            prop_assert!(
                pq.panels() == want.panels(),
                "({k},{n}) block={block} {isa:?}: panel bytes drifted"
            );
            prop_assert!(
                pq.scales().iter().zip(want.scales()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "({k},{n}) block={block} {isa:?}: scales not bit-identical"
            );
        }
        // every strict prefix of the frame body errors — never panics,
        // never a silently zero-padded panel
        let cut = g.usize(0, body.len() - 1);
        prop_assert!(
            container::decode_frame_into_packed_q(&body[..cut], Isa::Scalar).is_err(),
            "({k},{n}) block={block}: truncation to {cut}/{} decoded cleanly",
            body.len()
        );
        Ok(())
    });
}

#[test]
fn fused_packed_q_stream_corruption_errors_never_panics() {
    // an all-quantized container drained through next_packed_q: truncation
    // or a bit flip anywhere must fail with an error on the fused path too
    run_prop("codec_packed_q_corruption", 40, |g| {
        let n_t = g.usize(1, 4);
        let header =
            ContainerHeader { entry: "prop".into(), seed: 7, step: 0.0, n_tensors: Some(n_t) };
        let mut enc = e(Encoder::new(Vec::new(), &header))?;
        let mut shapes = Vec::new();
        for i in 0..n_t {
            let k = g.usize(1, 12);
            let n = g.usize(1, 10);
            let vals = g.vec_f32(k * n, -1.0, 1.0);
            let t = Tensor::from_f32(vals, &[k, n]).unwrap();
            let codec =
                if g.bool() { Codec::Int8 { block: n } } else { Codec::Int4 { block: n } };
            e(enc.write_tensor(&format!("t{i}"), &t, codec))?;
            shapes.push((k, n));
        }
        let (bytes, _total) = e(enc.finish())?;
        let drain_q = |b: &[u8]| -> anyhow::Result<usize> {
            let mut dec = Decoder::new(b)?;
            let mut got = 0;
            while let Some((_, pq, _)) = dec.next_packed_q(kernel::active())? {
                anyhow::ensure!((pq.k, pq.n) == shapes[got], "shape drifted at frame {got}");
                got += 1;
            }
            Ok(got)
        };
        prop_assert!(e(drain_q(&bytes))? == n_t, "pristine container lost frames");
        let cut = g.usize(0, bytes.len() - 1);
        prop_assert!(
            drain_q(&bytes[..cut]).is_err(),
            "prefix {cut}/{} decoded cleanly on the fused path",
            bytes.len()
        );
        let mut bad = bytes;
        let ix = g.usize(0, bad.len() - 1);
        let bit = g.usize(0, 7);
        bad[ix] ^= 1 << bit;
        prop_assert!(
            drain_q(&bad).is_err(),
            "bit flip at byte {ix} bit {bit} decoded cleanly on the fused path"
        );
        Ok(())
    });
}

#[test]
fn checkpoint_v2_roundtrips_through_files() {
    let dir = std::env::temp_dir().join(format!("mcnc_prop_codec_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    run_prop("ckpt_v2_roundtrip", 20, |g| {
        let seed = ((g.usize(0, u32::MAX as usize) as u64) << 32)
            | g.usize(0, u32::MAX as usize) as u64;
        let n_t = g.usize(1, 3);
        let mut tensors = Vec::new();
        for i in 0..n_t {
            let rows = g.usize(1, 16);
            let cols = g.usize(1, 16);
            let vals = g.vec_f32(rows * cols, -2.0, 2.0);
            tensors.push((format!("t{i}"), Tensor::from_f32(vals, &[rows, cols]).unwrap()));
        }
        let ck = Checkpoint {
            entry: format!("entry{}", g.usize(0, 99)),
            seed,
            step: g.f32(0.0, 1e4),
            tensors,
        };
        let path = dir.join(format!("case{seed:016x}.mcnc"));
        e(ck.save_v2(&path, Codec::Lossless))?;
        let back = e(Checkpoint::load(&path))?;
        std::fs::remove_file(&path).ok();
        prop_assert!(back.entry == ck.entry, "entry drifted");
        prop_assert!(back.seed == ck.seed, "seed {seed} drifted to {}", back.seed);
        prop_assert!(back.step == ck.step, "step drifted");
        prop_assert!(back.tensors.len() == ck.tensors.len(), "tensor count drifted");
        for ((an, at), (bn, bt)) in back.tensors.iter().zip(&ck.tensors) {
            prop_assert!(an == bn, "name drifted");
            let (af, bf) = (at.f32s().unwrap(), bt.f32s().unwrap());
            prop_assert!(
                af.iter().zip(bf).all(|(x, y)| x.to_bits() == y.to_bits()),
                "tensor {an} drifted"
            );
        }
        Ok(())
    });
    std::fs::remove_dir_all(&dir).ok();
}
