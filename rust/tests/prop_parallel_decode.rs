//! Properties of the parallel MCNC2 decode path (`Decoder::decode_all`):
//!
//! * decoded names/shapes/bytes are **bit-identical** to the serial
//!   `next_tensor` drain for every codec at every pool width {1, 2, 4, 8};
//! * corruption — truncation or a bit flip anywhere — detected on a pool
//!   worker still surfaces as an `Err`, never a panic, and CRC failures
//!   name the frame index and stream byte offset;
//! * the byte-level wire spec in `docs/FORMAT.md` is live: its worked
//!   example, hand-assembled here byte for byte, decodes to the documented
//!   tensor.

use mcnc::codec::{Codec, ContainerHeader, Decoder, Encoder, PackedPanels};
use mcnc::mcnc::kernel;
use mcnc::prop_assert;
use mcnc::tensor::Tensor;
use mcnc::util::prop::{run_prop, Gen};
use mcnc::util::threadpool::ThreadPool;

/// anyhow → property-error adapter.
fn e<T>(r: anyhow::Result<T>) -> Result<T, String> {
    r.map_err(|x| format!("{x:#}"))
}

/// A random multi-tensor container (random shapes, values, codecs),
/// checked to decode cleanly before being returned.
fn random_container(g: &mut Gen) -> Result<Vec<u8>, String> {
    let n_t = g.usize(1, 5);
    let header =
        ContainerHeader { entry: "prop".into(), seed: 7, step: 0.0, n_tensors: Some(n_t) };
    let mut enc = e(Encoder::new(Vec::new(), &header))?;
    for i in 0..n_t {
        let rows = g.usize(1, 12);
        let cols = g.usize(1, 12);
        let vals = g.vec_f32(rows * cols, -1.0, 1.0);
        let t = Tensor::from_f32(vals, &[rows, cols]).unwrap();
        let codec =
            *g.pick(&[Codec::Lossless, Codec::Int8 { block: 16 }, Codec::Int4 { block: 8 }]);
        e(enc.write_tensor(&format!("t{i}"), &t, codec))?;
    }
    let (bytes, _total) = e(enc.finish())?;
    match serial_drain(&bytes) {
        Ok(frames) if frames.len() == n_t => Ok(bytes),
        Ok(frames) => Err(format!("pristine container decoded {} of {n_t}", frames.len())),
        Err(err) => Err(format!("pristine container failed to decode: {err:#}")),
    }
}

fn serial_drain(bytes: &[u8]) -> anyhow::Result<Vec<(String, Tensor, Codec)>> {
    let mut dec = Decoder::new(bytes)?;
    let mut out = Vec::new();
    while let Some(f) = dec.next_tensor()? {
        out.push(f);
    }
    Ok(out)
}

fn parallel_drain(bytes: &[u8], threads: usize) -> anyhow::Result<Vec<(String, Tensor, Codec)>> {
    let pool = ThreadPool::new(threads);
    Decoder::new(bytes)?.decode_all_with(&pool)
}

#[test]
fn parallel_decode_bit_identical_to_serial_at_every_width() {
    run_prop("parallel_decode_identical", 40, |g| {
        let bytes = random_container(g)?;
        let serial = e(serial_drain(&bytes))?;
        for threads in [1usize, 2, 4, 8] {
            let par = e(parallel_drain(&bytes, threads))?;
            prop_assert!(
                par.len() == serial.len(),
                "{threads} threads decoded {} of {} tensors",
                par.len(),
                serial.len()
            );
            for (i, ((an, at, ac), (bn, bt, bc))) in par.iter().zip(&serial).enumerate() {
                prop_assert!(an == bn, "[{i}] name {an:?} vs {bn:?} ({threads} threads)");
                prop_assert!(ac == bc, "[{i}] codec drifted ({threads} threads)");
                prop_assert!(at.dims == bt.dims, "[{i}] shape drifted ({threads} threads)");
                let (af, bf) = (at.f32s().unwrap(), bt.f32s().unwrap());
                prop_assert!(
                    af.iter().zip(bf).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "[{i}] values not bit-identical ({threads} threads)"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn parallel_decode_truncation_always_errors() {
    run_prop("parallel_decode_truncation", 30, |g| {
        let bytes = random_container(g)?;
        let cut = g.usize(0, bytes.len() - 1);
        let threads = *g.pick(&[1usize, 2, 4, 8]);
        match parallel_drain(&bytes[..cut], threads) {
            Err(_) => Ok(()),
            Ok(out) => Err(format!(
                "prefix {cut}/{} decoded cleanly ({} tensors, {threads} threads)",
                bytes.len(),
                out.len()
            )),
        }
    });
}

#[test]
fn parallel_decode_bit_flips_always_error() {
    run_prop("parallel_decode_bitflip", 40, |g| {
        let bytes = random_container(g)?;
        let ix = g.usize(0, bytes.len() - 1);
        let bit = g.usize(0, 7);
        let threads = *g.pick(&[2usize, 4, 8]);
        let mut bad = bytes;
        bad[ix] ^= 1 << bit;
        match parallel_drain(&bad, threads) {
            Err(_) => Ok(()),
            Ok(_) => {
                Err(format!("bit flip at byte {ix} bit {bit} decoded cleanly ({threads} threads)"))
            }
        }
    });
}

/// A random 2-D container whose quantized frames all use row-aligned
/// scale blocks (admissible for the quantized-panel path); lossless
/// frames are mixed in so the per-frame codec-tag selection is exercised.
fn random_panels_container(g: &mut Gen) -> Result<Vec<u8>, String> {
    let n_t = g.usize(1, 5);
    let header =
        ContainerHeader { entry: "prop".into(), seed: 7, step: 0.0, n_tensors: Some(n_t) };
    let mut enc = e(Encoder::new(Vec::new(), &header))?;
    for i in 0..n_t {
        let k = g.usize(1, 12);
        let n = g.usize(1, 10);
        let vals = g.vec_f32(k * n, -1.0, 1.0);
        let t = Tensor::from_f32(vals, &[k, n]).unwrap();
        let codec = *g.pick(&[
            Codec::Lossless,
            Codec::Int8 { block: n },
            Codec::Int4 { block: 2 * n },
            Codec::Int8 { block: k * n },
        ]);
        e(enc.write_tensor(&format!("t{i}"), &t, codec))?;
    }
    let (bytes, _total) = e(enc.finish())?;
    Ok(bytes)
}

/// Serial panels drain: quantized frames through `next_packed_q`, f32
/// frames through `next_packed` — two passes over the stream, matched up
/// by the per-frame codec tag.
fn serial_panels_drain(
    bytes: &[u8],
    force_f32: bool,
) -> anyhow::Result<Vec<(String, PackedPanels, Codec)>> {
    let isa = kernel::active();
    let mut tags = Vec::new();
    {
        let mut dec = Decoder::new(bytes)?;
        while let Some((_, t, codec)) = dec.next_tensor()? {
            let quant = !force_f32
                && !codec.is_lossless()
                && t.dims.len() == 2
                && match codec {
                    Codec::Int8 { block } | Codec::Int4 { block } => {
                        kernel::quant_panels_admissible(t.dims[0], t.dims[1], block)
                    }
                    Codec::Lossless => false,
                };
            tags.push(quant);
        }
    }
    let mut out = Vec::new();
    for (i, &quant) in tags.iter().enumerate() {
        // re-open the stream and step to frame i on the matching path
        let mut dec = Decoder::new(bytes)?;
        for _ in 0..i {
            dec.next_tensor()?;
        }
        if quant {
            let (name, pq, codec) =
                dec.next_packed_q(isa)?.ok_or_else(|| anyhow::anyhow!("frame {i} vanished"))?;
            out.push((name, PackedPanels::Quant(pq), codec));
        } else {
            let (name, pb, codec) =
                dec.next_packed(isa)?.ok_or_else(|| anyhow::anyhow!("frame {i} vanished"))?;
            out.push((name, PackedPanels::F32(pb), codec));
        }
    }
    Ok(out)
}

#[test]
fn parallel_panels_decode_matches_serial_at_every_width() {
    run_prop("parallel_panels_identical", 30, |g| {
        let bytes = random_panels_container(g)?;
        for force_f32 in [false, true] {
            let serial = e(serial_panels_drain(&bytes, force_f32))?;
            for threads in [1usize, 2, 4, 8] {
                let pool = ThreadPool::new(threads);
                let par = e(e(Decoder::new(&bytes[..]))?.decode_all_panels_with(
                    &pool,
                    kernel::active(),
                    force_f32,
                ))?;
                prop_assert!(
                    par.len() == serial.len(),
                    "{threads} threads decoded {} of {} frames (force_f32 {force_f32})",
                    par.len(),
                    serial.len()
                );
                for (i, ((an, ap, ac), (bn, bp, bc))) in par.iter().zip(&serial).enumerate() {
                    let ctx = format!("[{i}] ({threads} threads, force_f32 {force_f32})");
                    prop_assert!(an == bn && ac == bc, "{ctx}: name/codec drifted");
                    match (ap, bp) {
                        (PackedPanels::Quant(a), PackedPanels::Quant(b)) => {
                            prop_assert!(
                                a.panels() == b.panels()
                                    && a.scales().iter().zip(b.scales()).all(|(x, y)| {
                                        x.to_bits() == y.to_bits()
                                    })
                                    && a.group_rows() == b.group_rows(),
                                "{ctx}: quantized panels not bit-identical"
                            );
                        }
                        (PackedPanels::F32(a), PackedPanels::F32(b)) => {
                            prop_assert!(
                                a.k == b.k
                                    && a.n == b.n
                                    && a.panels().iter().zip(b.panels()).all(|(x, y)| {
                                        x.to_bits() == y.to_bits()
                                    }),
                                "{ctx}: f32 panels not bit-identical"
                            );
                        }
                        _ => {
                            return Err(format!(
                                "{ctx}: path selection drifted (parallel is_quant {} vs {})",
                                ap.is_quant(),
                                bp.is_quant()
                            ))
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn parallel_panels_decode_corruption_always_errors() {
    run_prop("parallel_panels_corruption", 30, |g| {
        let bytes = random_panels_container(g)?;
        let threads = *g.pick(&[1usize, 2, 4, 8]);
        let pool = ThreadPool::new(threads);
        let drain = |b: &[u8]| -> anyhow::Result<usize> {
            Ok(Decoder::new(b)?
                .decode_all_panels_with(&pool, kernel::active(), false)?
                .len())
        };
        let n_ok = e(drain(&bytes))?;
        let cut = g.usize(0, bytes.len() - 1);
        prop_assert!(
            drain(&bytes[..cut]).is_err(),
            "prefix {cut}/{} decoded cleanly ({n_ok} frames expected, {threads} threads)",
            bytes.len()
        );
        let mut bad = bytes;
        let ix = g.usize(0, bad.len() - 1);
        let bit = g.usize(0, 7);
        bad[ix] ^= 1 << bit;
        prop_assert!(
            drain(&bad).is_err(),
            "bit flip at byte {ix} bit {bit} decoded cleanly ({threads} threads)"
        );
        Ok(())
    });
}

#[test]
fn parallel_decode_error_is_deterministic_and_indexed() {
    // corrupt two frame bodies; the parallel path must always report the
    // lowest-indexed one, with its index and byte offset, no matter how
    // workers are scheduled
    let header =
        ContainerHeader { entry: "det".into(), seed: 1, step: 0.0, n_tensors: Some(3) };
    let tensors: Vec<Tensor> =
        (0..3).map(|i| Tensor::from_f32(vec![i as f32 + 0.5; 64], &[64]).unwrap()).collect();
    let mut enc = Encoder::new(Vec::new(), &header).unwrap();
    for (i, t) in tensors.iter().enumerate() {
        enc.write_tensor(&format!("t{i}"), t, Codec::Lossless).unwrap();
    }
    let (bytes, _) = enc.finish().unwrap();

    // recompute the exact frame layout: each frame is
    // `varint body_len | body | crc32`, after the magic/header preamble
    let hlen = header.to_json().len();
    assert!(hlen < 128, "1-byte varint assumed");
    let pre = 6 + 1 + hlen + 4;
    let bodies: Vec<usize> = tensors
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let b = mcnc::codec::container::encode_frame(&format!("t{i}"), t, Codec::Lossless)
                .unwrap();
            assert!(b.len() < 128, "1-byte varint assumed");
            b.len()
        })
        .collect();
    let frame_off = |i: usize| pre + bodies[..i].iter().map(|l| 1 + l + 4).sum::<usize>();
    assert_eq!(frame_off(2) + 1 + bodies[2] + 4 + 1, bytes.len(), "layout math drifted");

    let mut bad = bytes.clone();
    bad[frame_off(1) + 3] ^= 0x20; // inside frame 1's body
    bad[frame_off(2) + 3] ^= 0x20; // inside frame 2's body
    for threads in [1usize, 2, 4, 8] {
        let err = match parallel_drain(&bad, threads) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("corrupt container decoded cleanly ({threads} threads)"),
        };
        assert!(err.contains("frame 1"), "{err}");
        assert!(err.contains(&format!("byte offset {}", frame_off(1))), "{err}");
        assert!(err.contains("CRC mismatch"), "{err}");
    }
}

// ---------------------------------------------------------------------------
// docs/FORMAT.md worked example
// ---------------------------------------------------------------------------

/// The exact byte stream spelled out in `docs/FORMAT.md` §Worked example:
/// a container holding one lossless tensor `"w"` of shape `[2]` with
/// values `[1.0, -2.0]`. If this test breaks, the spec and the decoder
/// have drifted apart — fix the document, not just the test.
#[rustfmt::skip]
const FORMAT_MD_EXAMPLE: &[u8] = &[
    // magic "MCNC2\n"
    0x4d, 0x43, 0x4e, 0x43, 0x32, 0x0a,
    // varint header length = 62
    0x3e,
    // header JSON: {"version":2,"entry":"demo","seed":"7","step":0,"n_tensors":1}
    0x7b, 0x22, 0x76, 0x65, 0x72, 0x73, 0x69, 0x6f, 0x6e, 0x22, 0x3a, 0x32,
    0x2c, 0x22, 0x65, 0x6e, 0x74, 0x72, 0x79, 0x22, 0x3a, 0x22, 0x64, 0x65,
    0x6d, 0x6f, 0x22, 0x2c, 0x22, 0x73, 0x65, 0x65, 0x64, 0x22, 0x3a, 0x22,
    0x37, 0x22, 0x2c, 0x22, 0x73, 0x74, 0x65, 0x70, 0x22, 0x3a, 0x30, 0x2c,
    0x22, 0x6e, 0x5f, 0x74, 0x65, 0x6e, 0x73, 0x6f, 0x72, 0x73, 0x22, 0x3a,
    0x31, 0x7d,
    // crc32(header), little-endian
    0x57, 0xe4, 0x6d, 0xd8,
    // varint frame body length = 17
    0x11,
    // frame body: name len 1, "w", ndims 1, dim 2, codec tag 0 (lossless)
    0x01, 0x77, 0x01, 0x02, 0x00,
    // four byte-plane symbol sections, each: flag 0 (raw) + 2 plane bytes
    0x00, 0x00, 0x00,             // plane 0 (f32 LE byte 0): [00, 00]
    0x00, 0x00, 0x00,             // plane 1: [00, 00]
    0x00, 0x80, 0x00,             // plane 2: [80, 00]
    0x00, 0x3f, 0xc0,             // plane 3: [3f, c0]
    // crc32(body), little-endian
    0xc9, 0x36, 0x1f, 0x46,
    // end marker: varint 0
    0x00,
];

#[test]
fn format_spec_worked_example_decodes() {
    assert_eq!(FORMAT_MD_EXAMPLE.len(), 96, "spec says the example is 96 bytes");
    let mut dec = Decoder::new(FORMAT_MD_EXAMPLE).unwrap();
    assert_eq!(dec.header().entry, "demo");
    assert_eq!(dec.header().seed, 7);
    assert_eq!(dec.header().step, 0.0);
    assert_eq!(dec.header().n_tensors, Some(1));
    let (name, t, codec) = dec.next_tensor().unwrap().expect("one tensor");
    assert_eq!(name, "w");
    assert_eq!(codec, Codec::Lossless);
    assert_eq!(t.dims, vec![2]);
    let w = t.f32s().unwrap();
    assert_eq!(w[0].to_bits(), 1.0f32.to_bits());
    assert_eq!(w[1].to_bits(), (-2.0f32).to_bits());
    assert!(dec.next_tensor().unwrap().is_none(), "end marker reached");

    // and the spec's example is what the encoder itself would emit for the
    // same frame (header JSON key order is an implementation detail, so
    // only the frame bytes are compared)
    let t = Tensor::from_f32(vec![1.0, -2.0], &[2]).unwrap();
    let body = mcnc::codec::container::encode_frame("w", &t, Codec::Lossless).unwrap();
    let spec_body = &FORMAT_MD_EXAMPLE[74..91];
    assert_eq!(body.as_slice(), spec_body, "encoder and spec drifted");
}
