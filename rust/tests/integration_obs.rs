//! Observability integration: a 4-shard chaos run under full tracing.
//!
//! The mock engine emits `decode` / `gemm` spans the way the real engine
//! does (caller-timed, inside the batch), the chaos wrapper injects a
//! panic, an error, and a shard kill, and the preload artifact arms the
//! supervisor's re-warm path. Afterwards the test asserts the two export
//! surfaces end to end:
//!
//! * the Prometheus text exposition parses line-by-line: families are
//!   present with one `# TYPE` header each, cumulative `_bucket` series
//!   are monotone, and every `+Inf` bucket equals its `_count`;
//! * the Chrome trace round-trips through `util/json`: one track per
//!   shard, non-negative `ph:"X"` spans, `restart` / `rewarm` instants
//!   from the supervisor, and at least one request whose `queue` span
//!   ends where its `batch` span begins with `decode` and `gemm` nested
//!   inside.
//!
//! The registry and the trace ring are process-global, so this binary
//! holds a single test function — parallel tests would contaminate each
//! other's counters and fight over the trace mode.

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::Result;
use mcnc::coordinator::{
    Batch, BatchPolicy, Chaos, ChaosCfg, EngineCore, FaultyEngine, ServeError, ServeStats, Server,
    ServerCfg, WarmStats,
};
use mcnc::obs::{self, export, trace, EngineObs, Kind, TraceMode};
use mcnc::util::json::{self, Json};

/// Mock engine that reports decode metrics and emits `decode` / `gemm`
/// spans from inside `run_batch`, mirroring the real engine's caller-side
/// instrumentation. With `require_warm`, task coverage only exists after
/// `preload` — so a restarted shard that still serves proves the
/// supervisor re-warmed the replacement engine.
struct ObsMock {
    shard: usize,
    n_tasks: usize,
    warmed: bool,
    eobs: EngineObs,
    stats: ServeStats,
}

impl ObsMock {
    fn new(shard: usize, n_tasks: usize) -> ObsMock {
        ObsMock {
            shard,
            n_tasks,
            warmed: false,
            eobs: EngineObs::register(shard),
            stats: ServeStats::default(),
        }
    }
}

impl EngineCore for ObsMock {
    fn seq(&self) -> usize {
        8
    }

    fn has_task(&self, task: usize) -> bool {
        task < self.n_tasks && self.warmed
    }

    fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>> {
        // Pretend to decode one frame per request, then run the GEMM;
        // both are timed caller-side and nest inside the batch span the
        // shard loop emits around this call.
        let t0 = Instant::now();
        obs::count_decoded_frame("mock");
        let t1 = Instant::now();
        self.eobs.record_decode(64 * batch.requests.len() as u64, 1, t1 - t0);
        trace::span(batch.trace_id(), self.shard, batch.task, Kind::Decode, t0, t1);
        let t2 = Instant::now();
        trace::span(batch.trace_id(), self.shard, batch.task, Kind::Gemm, t1, t2);
        self.stats.batches += 1;
        Ok(batch.requests.iter().map(|r| r.task as i32).collect())
    }

    fn stats_mut(&mut self) -> &mut ServeStats {
        &mut self.stats
    }

    fn into_stats(self) -> ServeStats {
        self.stats
    }

    fn preload(&mut self, _artifact: &Path) -> Result<WarmStats> {
        self.warmed = true;
        Ok(WarmStats { installed: self.n_tasks, ..WarmStats::default() })
    }
}

fn recv(rx: std::sync::mpsc::Receiver<mcnc::coordinator::Response>) -> mcnc::coordinator::Response {
    rx.recv_timeout(Duration::from_secs(30)).expect("response")
}

/// Parse every `<family>_bucket{...}` line of a Prometheus exposition,
/// asserting per-series cumulative monotonicity, and return the `+Inf`
/// value per series keyed by `family|labels-before-le`.
fn check_buckets(text: &str) -> HashMap<String, u64> {
    let mut last: HashMap<String, u64> = HashMap::new();
    let mut inf: HashMap<String, u64> = HashMap::new();
    for line in text.lines() {
        let Some((name_labels, val)) = line.rsplit_once(' ') else { continue };
        let Some(ix) = name_labels.find("_bucket{") else { continue };
        let family = &name_labels[..ix];
        let labels = &name_labels[ix + "_bucket{".len()..];
        let le_at = labels.find("le=").unwrap_or_else(|| panic!("bucket without le: {line}"));
        let key = format!("{family}|{}", &labels[..le_at]);
        let v: u64 = val.parse().unwrap_or_else(|_| panic!("bad bucket value: {line}"));
        let prev = last.insert(key.clone(), v).unwrap_or(0);
        assert!(v >= prev, "cumulative buckets must be monotone: {line}");
        if labels.contains("le=\"+Inf\"") {
            inf.insert(key, v);
        }
    }
    inf
}

/// Assert every `<family>_count{...}` line matches its series' `+Inf`
/// bucket from `check_buckets`.
fn check_counts(text: &str, inf: &HashMap<String, u64>) {
    let mut checked = 0usize;
    for line in text.lines() {
        let Some((name_labels, val)) = line.rsplit_once(' ') else { continue };
        let Some(ix) = name_labels.find("_count{") else { continue };
        let family = &name_labels[..ix];
        let labels = name_labels[ix + "_count{".len()..].trim_end_matches('}');
        let key = format!("{family}|{labels},");
        let c: u64 = val.parse().unwrap_or_else(|_| panic!("bad count value: {line}"));
        assert_eq!(inf.get(&key).copied(), Some(c), "+Inf bucket != _count for {line}");
        checked += 1;
    }
    assert!(checked > 0, "no histogram _count lines in the export");
}

#[test]
fn four_shard_chaos_run_exports_prometheus_and_chrome_trace() {
    trace::set_mode(TraceMode::All);
    trace::clear();

    let n_tasks = 8;
    let n_shards = 4;
    let chaos = Chaos::new(ChaosCfg {
        seed: 77,
        window: 12,
        panics: 1,
        errors: 1,
        kills: 1,
        ..ChaosCfg::default()
    });
    let cfg = ServerCfg {
        n_tasks,
        n_shards,
        policy: BatchPolicy { max_batch: 4, max_delay: Duration::from_millis(1) },
        heartbeat: Duration::from_millis(10),
        ..ServerCfg::default()
    };
    let c = chaos.clone();
    let server = Server::start_with(&cfg, move |shard| -> Result<FaultyEngine<ObsMock>> {
        c.factory_gate()?;
        Ok(c.wrap(ObsMock::new(shard, n_tasks)))
    })
    .expect("start obs server");
    server.preload(Path::new("obs-warm.mcnc2")).expect("preload");

    // Drive traffic until the fault schedule (panic, error, kill) is
    // spent; the kill forces a restart + re-warm on one shard.
    let mut submitted = 0u64;
    let mut completed = 0u64;
    for _wave in 0..200 {
        if chaos.exhausted() {
            break;
        }
        let rxs: Vec<_> = (0..n_tasks).map(|t| server.submit(t, vec![0; 8])).collect();
        submitted += n_tasks as u64;
        for rx in rxs {
            let r = recv(rx);
            match &r.result {
                Ok(tok) => {
                    assert_eq!(*tok, r.task as i32);
                    completed += 1;
                }
                Err(ServeError::Failed(_)) => {}
                Err(e) => panic!("unexpected outcome under faults: {e:?}"),
            }
        }
    }
    assert!(chaos.exhausted(), "fault schedule never completed");

    // Live snapshot through the Server API while shards are still up.
    let live = server.metrics_snapshot();
    assert!(live.counter_sum("mcnc_serve_requests_total") >= submitted);
    assert!(live.counter_sum("mcnc_codec_frames_total") >= 1, "mock decode never counted");

    // Post-schedule traffic converges; the restarted shard re-warmed.
    let rxs: Vec<_> = (0..n_tasks).map(|t| server.submit(t, vec![0; 8])).collect();
    submitted += n_tasks as u64;
    for rx in rxs {
        let r = recv(rx);
        assert!(r.is_ok(), "post-schedule failure (re-warm lost?): {:?}", r.result);
        completed += 1;
    }
    let stats = server.stop().expect("no shard may die permanently");
    assert_eq!(stats.restarts, 1, "the kill forces exactly one restart");

    // ---- Prometheus exposition (quiesced: all shard threads joined) ----
    let snap = obs::registry().snapshot();
    assert!(snap.counter_sum("mcnc_serve_requests_total") >= submitted);
    assert!(snap.counter_sum("mcnc_serve_restarts_total") >= 1);
    assert!(snap.counter_sum("mcnc_serve_batch_requests_total") >= completed);
    assert!(snap.counter_sum("mcnc_codec_decode_frames_total") >= 1);
    assert!(snap.histogram_merged("mcnc_serve_queue_wait_us").count() >= completed);

    let text = export::prometheus_text(&snap);
    for family in [
        "# TYPE mcnc_serve_requests_total counter",
        "# TYPE mcnc_serve_restarts_total counter",
        "# TYPE mcnc_serve_batches_total counter",
        "# TYPE mcnc_cache_entries gauge",
        "# TYPE mcnc_serve_queue_wait_us histogram",
        "# TYPE mcnc_serve_latency_us histogram",
        "# TYPE mcnc_codec_decode_us histogram",
    ] {
        assert_eq!(text.matches(family).count(), 1, "missing/duplicated {family:?}");
    }
    // All four shards report, with the task_mod label on batch counters.
    for s in 0..n_shards {
        assert!(
            text.contains(&format!("mcnc_serve_batch_requests_total{{shard=\"{s}\"}}")),
            "shard {s} missing from the exposition"
        );
    }
    assert!(text.contains("mcnc_serve_batches_total{shard=\""));
    assert!(text.contains(",task_mod=\""));
    let inf = check_buckets(&text);
    assert!(!inf.is_empty(), "no histogram buckets in the export");
    check_counts(&text, &inf);

    // The JSON snapshot parses back through util/json too.
    let parsed = json::parse(&json::to_string(&export::snapshot_json(&snap)))
        .expect("snapshot JSON parses");
    assert!(
        !parsed.get("histograms").and_then(Json::as_arr).expect("histograms").is_empty(),
        "snapshot JSON lost the histograms"
    );

    // ---- Chrome trace round-trip ----
    let recs = trace::records();
    trace::set_mode(TraceMode::Off);
    let parsed = json::parse(&export::chrome_trace(&recs)).expect("chrome trace parses");
    let events = parsed.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
    // (name, tid, trace_id, ts, dur) per complete span.
    let mut xs: Vec<(String, f64, u64, f64, f64)> = Vec::new();
    let mut instants: Vec<String> = Vec::new();
    let mut tracks = 0usize;
    for e in events {
        let name = e.get("name").and_then(Json::as_str).expect("event name").to_string();
        match e.get("ph").and_then(Json::as_str) {
            Some("X") => {
                let tid = e.get("tid").and_then(Json::as_f64).expect("tid");
                let ts = e.get("ts").and_then(Json::as_f64).expect("ts");
                let dur = e.get("dur").and_then(Json::as_f64).expect("dur");
                assert!(ts >= 0.0 && dur >= 0.0, "negative span: {name} ts={ts} dur={dur}");
                let tic = e
                    .get("args")
                    .and_then(|a| a.get("trace_id"))
                    .and_then(Json::as_f64)
                    .expect("trace_id") as u64;
                xs.push((name, tid, tic, ts, dur));
            }
            Some("i") => {
                assert_eq!(e.get("s").and_then(Json::as_str), Some("t"), "{name}");
                instants.push(name);
            }
            Some("M") => tracks += 1,
            ph => panic!("unexpected ph {ph:?}"),
        }
    }
    assert!(tracks >= n_shards, "expected a named track per shard, got {tracks}");
    assert!(instants.iter().any(|n| n == "restart"), "no restart instant: {instants:?}");
    assert!(instants.iter().any(|n| n == "rewarm"), "no rewarm instant: {instants:?}");

    // Span nesting: decode/gemm sit inside their batch span (same shard
    // track, same trace id); the queue span ends where the batch begins.
    let batches: Vec<_> = xs.iter().filter(|x| x.0 == "batch").collect();
    assert!(!batches.is_empty(), "no batch spans recorded");
    for (name, tid, tic, ts, dur) in &xs {
        match name.as_str() {
            "decode" | "gemm" => {
                let inside = batches.iter().any(|b| {
                    b.1 == *tid && b.2 == *tic && b.3 <= *ts && ts + dur <= b.3 + b.4
                });
                assert!(inside, "{name} span (trace {tic}) not nested in its batch span");
            }
            "queue" => {
                if let Some(b) = batches.iter().find(|b| b.2 == *tic) {
                    assert!(ts + dur <= b.3, "queue span overruns batch start (trace {tic})");
                }
            }
            _ => {}
        }
    }
    // At least one request journeyed queue → batch ⊇ decode, gemm.
    let has = |n: &str, t: u64| xs.iter().any(|x| x.0 == n && x.2 == t);
    let full = batches
        .iter()
        .filter(|b| has("queue", b.2) && has("decode", b.2) && has("gemm", b.2))
        .count();
    assert!(full >= 1, "no request shows the full queue→batch→decode→gemm journey");
}
