//! Loopback integration of the MCNP1 socket front-end: real sockets, real
//! listener poll loop, mock engines — no PJRT artifacts required. Covers
//! the tentpole invariants end to end:
//!
//! * N concurrent connections × M shards: every request answered exactly
//!   once, predictions prove shard affinity survives the wire;
//! * per-request error replies (unknown task) leave the connection usable;
//! * admission backpressure surfaces as typed `ERR_REJECTED` replies;
//! * breaker fast-fails arrive as typed protocol errors, not resets;
//! * shutdown drains: every in-flight request is answered and flushed
//!   before the socket closes;
//! * protocol violations (bad preamble, server-only messages) get a final
//!   `ConnErr` and a close, without disturbing other connections;
//! * chaos-over-socket: shard kills/panics/errors behind a live socket
//!   leave connections intact and every request answered (ok or `Failed`).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;
use mcnc::coordinator::workload::{open_loop, replay_socket};
use mcnc::coordinator::{
    Batch, BatchPolicy, BreakerCfg, Chaos, ChaosCfg, EngineCore, ServeStats, Server, ServerCfg,
};
use mcnc::data::MarkovLm;
use mcnc::net::protocol::{
    encode_frame, Deframer, Msg, ERR_FAILED, ERR_REJECTED, NET_MAGIC,
};
use mcnc::net::{NetCfg, NetListener, NetReport};

// ---------------------------------------------------------------------------
// Mock engine + harness
// ---------------------------------------------------------------------------

/// Deterministic mock mirroring `integration_server.rs`: predicts
/// `shard * 1000 + task`, with optional failure injection and a gate the
/// test holds shut to park a shard mid-batch.
struct MockEngine {
    shard: usize,
    n_tasks: usize,
    seq: usize,
    fail_task: Option<usize>,
    gate: Option<Arc<Mutex<()>>>,
    entered: Arc<AtomicUsize>,
    stats: ServeStats,
}

#[derive(Clone)]
struct MockCfg {
    n_tasks: usize,
    seq: usize,
    fail_task: Option<usize>,
    gate: Option<Arc<Mutex<()>>>,
    entered: Arc<AtomicUsize>,
}

impl MockCfg {
    fn new(n_tasks: usize, seq: usize) -> MockCfg {
        MockCfg {
            n_tasks,
            seq,
            fail_task: None,
            gate: None,
            entered: Arc::new(AtomicUsize::new(0)),
        }
    }

    fn server(&self, cfg: &ServerCfg) -> Server {
        let mock = self.clone();
        Server::start_with(cfg, move |shard| -> Result<MockEngine> {
            Ok(MockEngine {
                shard,
                n_tasks: mock.n_tasks,
                seq: mock.seq,
                fail_task: mock.fail_task,
                gate: mock.gate.clone(),
                entered: Arc::clone(&mock.entered),
                stats: ServeStats::default(),
            })
        })
        .expect("start mock server")
    }
}

impl EngineCore for MockEngine {
    fn seq(&self) -> usize {
        self.seq
    }

    fn has_task(&self, task: usize) -> bool {
        task < self.n_tasks
    }

    fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        if let Some(gate) = &self.gate {
            drop(gate.lock().unwrap());
        }
        if self.fail_task == Some(batch.task) {
            anyhow::bail!("injected failure for task {}", batch.task);
        }
        self.stats.batches += 1;
        Ok(batch.requests.iter().map(|r| (self.shard * 1000 + r.task) as i32).collect())
    }

    fn stats_mut(&mut self) -> &mut ServeStats {
        &mut self.stats
    }

    fn into_stats(self) -> ServeStats {
        self.stats
    }
}

fn mock_server_cfg(n_shards: usize, max_batch: usize) -> ServerCfg {
    ServerCfg {
        n_shards,
        policy: BatchPolicy { max_batch, max_delay: Duration::from_millis(1) },
        heartbeat: Duration::from_millis(10),
        ..ServerCfg::default()
    }
}

/// Bind an ephemeral loopback listener, run its poll loop in a scoped
/// thread while `f` drives clients at the bound address, then stop, drain
/// and hand back both `f`'s result and the listener's `NetReport`.
fn with_listener<R>(server: &Server, f: impl FnOnce(SocketAddr) -> R) -> (R, NetReport) {
    let listener = NetListener::bind(NetCfg::default()).expect("bind loopback");
    let addr = listener.local_addr().expect("local addr");
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let pump = scope.spawn(|| listener.run(server, &stop));
        let r = f(addr);
        stop.store(true, Ordering::Relaxed);
        let report = pump.join().expect("listener thread").expect("listener run");
        (r, report)
    })
}

/// Minimal blocking MCNP1 client for direct frame-level assertions.
struct Client {
    stream: TcpStream,
    de: Deframer,
    buf: Vec<u8>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let mut c = Client::connect_raw(addr);
        c.stream.write_all(NET_MAGIC).expect("preamble");
        c
    }

    /// Connect without sending the preamble (for handshake tests).
    fn connect_raw(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs(30))).expect("read timeout");
        Client { stream, de: Deframer::new(), buf: vec![0u8; 16 * 1024] }
    }

    fn send(&mut self, m: &Msg) {
        self.stream.write_all(&encode_frame(m)).expect("send frame");
    }

    fn send_bytes(&mut self, bytes: &[u8]) {
        self.stream.write_all(bytes).expect("send bytes");
    }

    /// Next message; panics on timeout or EOF.
    fn recv(&mut self) -> Msg {
        self.try_recv().expect("connection closed while awaiting a reply")
    }

    /// Next message, or `None` on clean EOF.
    fn try_recv(&mut self) -> Option<Msg> {
        loop {
            if let Some(m) = self.de.next().expect("deframe reply") {
                return Some(m);
            }
            let n = self.stream.read(&mut self.buf).expect("read reply");
            if n == 0 {
                return None;
            }
            self.de.push(&self.buf[..n]);
        }
    }
}

fn req(id: u64, task: u64, seq: usize) -> Msg {
    Msg::Req { id, task, tokens: vec![0; seq], deadline_us: 0 }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[test]
fn loopback_exactly_once_across_connections_and_shards() {
    let n_conns = 8;
    let n_reqs = 25u64;
    let n_shards = 3;
    let mock = MockCfg::new(6, 8);
    let server = mock.server(&mock_server_cfg(n_shards, 4));
    let ((), report) = with_listener(&server, |addr| {
        std::thread::scope(|scope| {
            for conn in 0..n_conns {
                scope.spawn(move || {
                    let mut c = Client::connect(addr);
                    for i in 0..n_reqs {
                        // wire ids are per-connection; reuse across conns
                        // is legal and must not cross-talk
                        c.send(&req(i, (conn as u64 + i) % 6, 8));
                    }
                    let mut seen = std::collections::HashMap::new();
                    for _ in 0..n_reqs {
                        match c.recv() {
                            Msg::ReplyOk { id, trace, token, .. } => {
                                assert!(seen.insert(id, trace).is_none(), "wire id {id} twice");
                                let task = (conn as u64 + id) % 6;
                                let shard = task as usize % n_shards;
                                assert_eq!(token, (shard * 1000) as i32 + task as i32);
                            }
                            other => panic!("conn {conn}: unexpected {other:?}"),
                        }
                    }
                    assert_eq!(seen.len(), n_reqs as usize);
                    // trace ids are server-global: all distinct within a conn
                    let traces: std::collections::HashSet<u64> =
                        seen.values().copied().collect();
                    assert_eq!(traces.len(), n_reqs as usize, "trace ids collided");
                });
            }
        });
    });
    assert_eq!(report.accepted, n_conns as u64);
    assert_eq!(report.requests, n_conns as u64 * n_reqs);
    assert_eq!(report.frames_in, n_conns as u64 * n_reqs);
    assert_eq!(report.frames_out, n_conns as u64 * n_reqs);
    assert_eq!(report.protocol_errors, 0);
    let stats = server.stop().unwrap();
    assert_eq!(stats.latency.count(), n_conns as u64 * n_reqs);
    assert_eq!(stats.errors, 0);
}

#[test]
fn unknown_task_gets_error_reply_and_connection_survives() {
    let mock = MockCfg::new(4, 8);
    let server = mock.server(&mock_server_cfg(2, 4));
    let ((), report) = with_listener(&server, |addr| {
        let mut c = Client::connect(addr);
        c.send(&req(1, 99, 8)); // unknown task
        c.send(&req(2, 250, 8)); // wrong token count for a known task
        match c.recv() {
            Msg::ReplyErr { id: 1, code, msg, .. } => {
                assert_eq!(code, ERR_FAILED);
                assert!(!msg.is_empty(), "error reply should say why");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(matches!(c.recv(), Msg::ReplyErr { id: 2, code: ERR_FAILED, .. }));
        // same connection still serves
        c.send(&req(3, 1, 8));
        match c.recv() {
            Msg::ReplyOk { id: 3, token, .. } => assert_eq!(token, 1001),
            other => panic!("unexpected {other:?}"),
        }
    });
    assert_eq!(report.protocol_errors, 0, "error replies are not protocol errors");
    let stats = server.stop().unwrap();
    assert_eq!(stats.errors, 2);
}

#[test]
fn backpressure_surfaces_as_typed_rejected_replies() {
    let gate = Arc::new(Mutex::new(()));
    let mut mock = MockCfg::new(4, 8);
    mock.gate = Some(Arc::clone(&gate));
    let cfg = ServerCfg {
        n_shards: 1,
        queue_cap: 2,
        policy: BatchPolicy { max_batch: 1, max_delay: Duration::ZERO },
        heartbeat: Duration::from_millis(10),
        ..ServerCfg::default()
    };
    let server = mock.server(&cfg);
    let ((), _report) = with_listener(&server, |addr| {
        let mut c = Client::connect(addr);
        let guard = gate.lock().unwrap();
        c.send(&req(0, 0, 8));
        let t0 = std::time::Instant::now();
        while mock.entered.load(Ordering::SeqCst) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "shard never started");
            std::thread::sleep(Duration::from_millis(1));
        }
        // shard parked mid-batch: the bounded admission queue (cap 2) must
        // overflow and every overflow arrive as a typed ERR_REJECTED reply
        for i in 1..=40u64 {
            c.send(&req(i, 0, 8));
        }
        drop(guard);
        let mut ok = 0;
        let mut rejected = 0;
        for _ in 0..41 {
            match c.recv() {
                Msg::ReplyOk { .. } => ok += 1,
                Msg::ReplyErr { code, msg, .. } => {
                    assert_eq!(code, ERR_REJECTED, "{msg}");
                    assert!(msg.contains("queue full"), "{msg}");
                    rejected += 1;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(ok, 3, "parked request + queue_cap complete");
        assert_eq!(rejected, 38);
    });
    let stats = server.stop().unwrap();
    assert_eq!(stats.rejected, 38);
}

#[test]
fn breaker_fastfail_is_a_typed_protocol_error_not_a_reset() {
    let mut mock = MockCfg::new(2, 8);
    mock.fail_task = Some(0);
    let cfg = ServerCfg {
        n_shards: 1,
        policy: BatchPolicy { max_batch: 1, max_delay: Duration::ZERO },
        heartbeat: Duration::from_millis(10),
        breaker: BreakerCfg { threshold: 2, ..BreakerCfg::default() },
        ..ServerCfg::default()
    };
    let server = mock.server(&cfg);
    let ((), report) = with_listener(&server, |addr| {
        let mut c = Client::connect(addr);
        // two consecutive batch failures trip the breaker …
        for i in 0..2u64 {
            c.send(&req(i, 0, 8));
            match c.recv() {
                Msg::ReplyErr { code, msg, .. } => {
                    assert_eq!(code, ERR_FAILED);
                    assert!(msg.contains("injected failure"), "{msg}");
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // … and the fast-fail arrives as a typed reply on a live socket
        c.send(&req(2, 0, 8));
        match c.recv() {
            Msg::ReplyErr { code, msg, .. } => {
                assert_eq!(code, ERR_REJECTED);
                assert!(msg.contains("circuit open"), "{msg}");
            }
            other => panic!("unexpected {other:?}"),
        }
    });
    assert_eq!(report.protocol_errors, 0);
    let stats = server.stop().unwrap();
    assert!(stats.breaker_opens >= 1);
    assert!(stats.breaker_fastfail >= 1);
}

#[test]
fn shutdown_drains_inflight_requests_before_closing() {
    let gate = Arc::new(Mutex::new(()));
    let mut mock = MockCfg::new(4, 8);
    mock.gate = Some(Arc::clone(&gate));
    let server = mock.server(&mock_server_cfg(1, 1));
    let listener = NetListener::bind(NetCfg::default()).expect("bind loopback");
    let addr = listener.local_addr().expect("local addr");
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let pump = scope.spawn(|| listener.run(&server, &stop));
        let mut c = Client::connect(addr);
        let guard = gate.lock().unwrap();
        for i in 0..3u64 {
            c.send(&req(i, 0, 8));
        }
        let t0 = std::time::Instant::now();
        while mock.entered.load(Ordering::SeqCst) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "shard never started");
            std::thread::sleep(Duration::from_millis(1));
        }
        // give the poll loop time to read + submit all three requests,
        // then order a shutdown while they are in flight
        std::thread::sleep(Duration::from_millis(100));
        stop.store(true, Ordering::Relaxed);
        drop(guard);
        let mut ok = std::collections::HashSet::new();
        while let Some(m) = c.try_recv() {
            match m {
                Msg::ReplyOk { id, .. } => {
                    assert!(ok.insert(id));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // clean EOF only after every in-flight request was answered
        assert_eq!(ok.len(), 3, "drain lost replies: got {ok:?}");
        let report = pump.join().expect("listener thread").expect("listener run");
        assert_eq!(report.requests, 3);
        assert_eq!(report.frames_out, 3);
        assert_eq!(report.closed, report.accepted);
    });
    server.stop().unwrap();
}

#[test]
fn protocol_violations_get_conn_err_and_do_not_disturb_neighbors() {
    let mock = MockCfg::new(4, 8);
    let server = mock.server(&mock_server_cfg(2, 4));
    let ((), report) = with_listener(&server, |addr| {
        let mut good = Client::connect(addr);

        // bad preamble → ConnErr, then EOF
        let mut bad = Client::connect_raw(addr);
        bad.send_bytes(b"HTTP/1\n");
        match bad.try_recv() {
            Some(Msg::ConnErr { msg }) => assert!(msg.contains("preamble"), "{msg}"),
            other => panic!("expected ConnErr, got {other:?}"),
        }
        assert!(bad.try_recv().is_none(), "connection must close after ConnErr");

        // server-only message from a client → ConnErr, then EOF
        let mut rogue = Client::connect(addr);
        rogue.send(&Msg::Pong { nonce: 1 });
        match rogue.try_recv() {
            Some(Msg::ConnErr { msg }) => assert!(msg.contains("server-only"), "{msg}"),
            other => panic!("expected ConnErr, got {other:?}"),
        }
        assert!(rogue.try_recv().is_none());

        // ping/pong and requests on the good connection are unaffected
        good.send(&Msg::Ping { nonce: 7 });
        assert_eq!(good.recv(), Msg::Pong { nonce: 7 });
        good.send(&req(1, 1, 8));
        assert!(matches!(good.recv(), Msg::ReplyOk { id: 1, .. }));
    });
    assert_eq!(report.protocol_errors, 2);
    assert_eq!(report.accepted, 3);
    server.stop().unwrap();
}

#[test]
fn chaos_over_socket_answers_every_request_and_keeps_connections_alive() {
    // the chaos schedule of table4c — panics, errors and a shard kill —
    // driven through a live socket: connections must survive the faults,
    // stranded requests must come back as typed Failed replies, and no
    // request may go unanswered
    let n_tasks = 6;
    let chaos = Chaos::new(ChaosCfg {
        seed: 0xBEEF,
        window: 16,
        panics: 2,
        errors: 2,
        kills: 1,
        ..ChaosCfg::default()
    });
    let cfg = ServerCfg {
        n_tasks,
        n_shards: 2,
        policy: BatchPolicy { max_batch: 8, max_delay: Duration::from_millis(2) },
        heartbeat: Duration::from_millis(10),
        seed: 1,
        ..ServerCfg::default()
    };
    let c = chaos.clone();
    let server = Server::start_with(&cfg, move |_shard| {
        c.factory_gate()?;
        Ok(c.wrap(MockEngine {
            shard: 0,
            n_tasks,
            seq: 32,
            fail_task: None,
            gate: None,
            entered: Arc::new(AtomicUsize::new(0)),
            stats: ServeStats::default(),
        }))
    })
    .expect("start chaos mock server");
    let lm = MarkovLm::base(1, 128, 32);
    let schedule = open_loop(7, 300.0, Duration::from_secs_f64(0.5), n_tasks, 1.0);
    let (rep, report) = with_listener(&server, |addr| {
        replay_socket(
            &addr.to_string(),
            &lm,
            9,
            &schedule,
            4,
            None,
            Duration::from_secs(30),
        )
        .expect("socket replay")
    });
    assert_eq!(rep.sent, schedule.len());
    assert_eq!(rep.conn_errors, 0, "chaos must not surface as connection errors");
    assert_eq!(rep.missing, 0, "every request must be answered: {rep:?}");
    assert_eq!(rep.answered(), rep.sent);
    assert!(rep.ok > 0, "no request survived the fault schedule: {rep:?}");
    assert_eq!(report.protocol_errors, 0);
    let stats = server.stop().unwrap();
    assert!(
        stats.batch_panics + stats.restarts + stats.errors > 0,
        "chaos schedule injected nothing — the test is vacuous"
    );
}
