#!/usr/bin/env bash
# CI gate: formatting, lints, tier-1 build + tests. Everything runs fully
# offline (vendored anyhow, PJRT behind the off-by-default `pjrt` feature).
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy (-D warnings; every unsafe block needs // SAFETY:) =="
cargo clippy --workspace --all-targets -- -D warnings -D clippy::undocumented_unsafe_blocks

echo "== mcnc-lint (repo invariants: safety/dispatch/determinism/wire-format) =="
# exits nonzero on any unsuppressed finding; see docs/LINTS.md
cargo run -q -p mcnc-lint -- rust/src

echo "== mcnc-lint self-tests (golden fixtures + tree self-check) =="
cargo test -q -p mcnc-lint

echo "== cargo doc (-D warnings; rustdoc headers + intra-doc links) =="
# -p mcnc: the vendored anyhow twin is not held to the doc gate
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p mcnc

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== serving coordinator (mock-engine tests; no artifacts needed) =="
cargo test -q --test integration_server

echo "== fault tolerance: deterministic chaos schedules (pinned seeds) =="
cargo test -q --test integration_chaos

echo "== socket front-end: loopback MCNP1 integration + chaos-over-socket =="
cargo test -q --test integration_net

echo "== observability: Prometheus/Chrome-trace exports under chaos =="
cargo test -q --test integration_obs

echo "== observability hook overhead (perf_micro smoke; obs section only) =="
cargo bench --bench perf_micro -- --smoke

echo "== availability under faults + socket sweep (table4 smoke; mock, no artifacts) =="
cargo bench --bench table4_peft_serving -- --smoke

echo "== codec property tests (corruption handling must fail tier-1) =="
cargo test -q -p mcnc --test prop_codec

echo "== MCNP1 protocol fuzz/property tests + docs/PROTOCOL.md worked example =="
cargo test -q -p mcnc --test prop_net_protocol

echo "== parallel decode determinism + docs/FORMAT.md worked example =="
cargo test -q -p mcnc --test prop_parallel_decode

echo "== int8 GEMM oracle parity (analytic bound + cross-ISA bit-identity) =="
cargo test -q -p mcnc --test prop_int8_gemm

echo "== compressed-domain serving (quantized panels over MCNP1 vs f32 oracle) =="
cargo test -q -p mcnc --test integration_quant_serving

echo "== doctests (Encoder/Decoder, Server examples must stay runnable) =="
cargo test -q -p mcnc --doc

echo "== decode pipeline smoke (table8 bench, tiny fixtures, no JSON) =="
cargo bench --bench table8_transfer -- --smoke

echo "CI OK"
