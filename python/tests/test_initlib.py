"""Init-law tests: every manifest init kind, shapes, and zero-delta wiring."""

import numpy as np
import pytest

from compile import initlib, models, rng
from compile.methods import Dense, Lora, Mcnc, McncLora, NolaLora, Registry
from compile.genutil import GenCfg, make_weights

MLP = models.MlpCfg(hidden=16)
REG = Registry(MLP.leaves())
REGM = {"Dc": REG.Dc, "R": REG.R, "leaves": [l.to_meta() for l in MLP.leaves()]}


def test_comp_leaves_layout():
    v = initlib.init_tensor({"kind": "comp_leaves"}, (REG.Dc,), REGM, 5)
    assert v.shape == (REG.Dc,)
    # first leaf (w1) drawn from its dedicated substream:
    first, _ = REG.comp[0]
    s = rng.substream(5, rng.TAG_THETA0 + 0)
    expect = rng.symmetric_f32(s, first.size, first.param)
    np.testing.assert_array_equal(v[: first.size], expect)


def test_raw_leaves_zeros_and_ones():
    v = initlib.init_tensor({"kind": "raw_leaves"}, (max(REG.R, 1),), REGM, 5)
    assert v.shape[0] == max(REG.R, 1)
    # mlp raw leaves are all biases (zeros)
    assert np.all(v == 0.0)


def test_gen_layer_matches_make_weights():
    cfg = GenCfg(k=3, d=11, width=5, depth=3)
    for i in range(3):
        v = initlib.init_tensor({"kind": "gen_layer", "layer": i,
                                 "gen": cfg.to_meta()},
                                cfg.layer_shapes()[i], REGM, 21)
        np.testing.assert_array_equal(v, make_weights(cfg, 21)[i])


def test_lora0_structure():
    r = 3
    v = initlib.init_tensor({"kind": "lora0", "rank": r}, None, REGM, 9)
    da = sum(l["lora"][0] * r for l in REGM["leaves"] if l["lora"] and l["compress"])
    db = sum(r * l["lora"][1] for l in REGM["leaves"] if l["lora"] and l["compress"])
    assert v.shape == (da + db,)
    assert np.abs(v[:da]).max() > 0  # A part random
    assert np.all(v[da:] == 0.0)  # B part zero


def test_nola_basis_sizes_and_streams():
    m, r = 4, 2
    va = initlib.init_tensor({"kind": "nola_basis", "side": "a", "m": m,
                              "rank": r}, None, REGM, 13)
    vb = initlib.init_tensor({"kind": "nola_basis", "side": "b", "m": m,
                              "rank": r}, None, REGM, 13)
    targets = [l for l in REGM["leaves"] if l["compress"] and l["lora"]]
    assert va.size == sum(m * l["lora"][0] * r for l in targets)
    assert vb.size == sum(m * r * l["lora"][1] for l in targets)
    assert not np.array_equal(va[: vb.size], vb)


def test_nola_coef_bound():
    m = 16
    v = initlib.init_tensor({"kind": "nola_coef", "m": m}, (3, m), REGM, 1)
    assert v.shape == (3, m)
    assert np.abs(v).max() <= 1.0 / np.sqrt(m) + 1e-7


def test_zeros_ones():
    assert np.all(initlib.init_tensor({"kind": "zeros"}, (4, 2), REGM, 0) == 0)
    assert np.all(initlib.init_tensor({"kind": "ones"}, (7,), REGM, 0) == 1)


def test_init_all_covers_method_specs():
    for method in [Dense(REG), Mcnc(REG, GenCfg(k=3, d=200, width=16)),
                   Lora(REG, 2), McncLora(REG, 2, GenCfg(k=3, d=64, width=8)),
                   NolaLora(REG, 2, 4)]:
        specs = [s.to_meta() for s in method.statics() + method.trainables()]
        out = initlib.init_all(specs, REGM, 3)
        for s in specs:
            v = out[s["name"]]
            assert list(v.reshape(tuple(s["shape"])).shape) == s["shape"], s["name"]


def test_seed_sensitivity():
    a = initlib.init_tensor({"kind": "comp_leaves"}, (REG.Dc,), REGM, 1)
    b = initlib.init_tensor({"kind": "comp_leaves"}, (REG.Dc,), REGM, 2)
    assert not np.array_equal(a, b)


def test_unknown_kind_raises():
    with pytest.raises(ValueError):
        initlib.init_tensor({"kind": "nope"}, (1,), REGM, 0)
