"""SplitMix64 stream tests + the cross-language golden vectors.

The golden constants here are duplicated in ``rust/src/util/prng.rs`` tests;
if either side changes, both fail. Keep in sync.
"""

import numpy as np
import pytest

from compile import rng


def test_mix_golden():
    # splitmix64(seed=0) canonical first outputs (state += GAMMA then mix).
    assert rng.raw_u64(0, 3).tolist() == [
        0xE220A8397B1DCDAF,
        0x6E789E6AA1B965F4,
        0x06C45D188009454F,
    ]


def test_mix_golden_nonzero_seed():
    assert rng.raw_u64(42, 2).tolist() == [
        0xBDD732262FEB6E95,
        0x28EFE333B266F103,
    ]


def test_substream_deterministic():
    a = rng.substream(7, rng.TAG_THETA0)
    b = rng.substream(7, rng.TAG_THETA0)
    c = rng.substream(7, rng.TAG_THETA0 + 1)
    assert a == b and a != c
    assert 0 <= a < 2**64


def test_uniform_range_and_determinism():
    u = rng.uniform_f32(123, 10_000)
    assert u.dtype == np.float32
    assert (u >= 0).all() and (u < 1).all()
    assert np.array_equal(u, rng.uniform_f32(123, 10_000))
    # mean ~ 0.5
    assert abs(float(u.mean()) - 0.5) < 0.02


def test_uniform_f32_golden():
    u = rng.uniform_f32(1, 4)
    expect = (np.array(rng.raw_u64(1, 4) >> np.uint64(40), dtype=np.float32)
              * np.float32(2.0**-24))
    assert np.array_equal(u, expect)


def test_symmetric_bounds():
    s = rng.symmetric_f32(9, 5000, 0.25)
    assert (np.abs(s) <= 0.25).all()
    assert abs(float(s.mean())) < 0.01
    assert s.min() < -0.2 and s.max() > 0.2


def test_normal_moments():
    z = rng.normal_f32(11, 100_000, std=2.0)
    assert abs(float(z.mean())) < 0.05
    assert abs(float(z.std()) - 2.0) < 0.05


def test_prefix_stability():
    """Stream prefix must not depend on the requested length."""
    long = rng.uniform_f32(5, 1000)
    short = rng.uniform_f32(5, 10)
    assert np.array_equal(long[:10], short)


@pytest.mark.parametrize("n", [0, 1, 2, 7])
def test_normal_odd_lengths(n):
    assert rng.normal_f32(3, n).shape == (n,)
