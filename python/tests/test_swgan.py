"""SWGAN generator-training tests (Fig 2 / Table 9 substrate)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import rng
from compile.genutil import GenCfg
from compile.swgan import build_swgan_step, sw2_distance


def _cloud(seed, b, d):
    z = rng.normal_f32(seed, b * d).reshape(b, d)
    return jnp.asarray(z / (np.linalg.norm(z, axis=1, keepdims=True) + 1e-8))


def test_sw2_zero_for_identical():
    x = _cloud(1, 64, 3)
    proj = jnp.asarray(rng.normal_f32(2, 3 * 8).reshape(3, 8))
    assert float(sw2_distance(x, x, proj)) < 1e-10


def test_sw2_positive_and_symmetricish():
    x, t = _cloud(1, 64, 3), _cloud(5, 64, 3)
    proj = jnp.asarray(rng.normal_f32(2, 3 * 8).reshape(3, 8))
    d1 = float(sw2_distance(x, t, proj))
    d2 = float(sw2_distance(t, x, proj))
    assert d1 > 0
    np.testing.assert_allclose(d1, d2, rtol=1e-5)


def test_sw2_detects_collapse():
    """A collapsed cloud (all mass at one pole) is far from uniform."""
    t = _cloud(3, 128, 3)
    x = jnp.broadcast_to(jnp.asarray([1.0, 0.0, 0.0]), (128, 3))
    proj = jnp.asarray(rng.normal_f32(4, 3 * 16).reshape(3, 16))
    assert float(sw2_distance(x, t, proj)) > 0.1


def test_custom_vjp_matches_fd():
    """Hand-written sorted-diff VJP vs finite differences."""
    x = jnp.asarray(rng.normal_f32(1, 10))
    t = jnp.asarray(rng.normal_f32(2, 10))
    proj = jnp.eye(1)

    def f(xx):
        return sw2_distance(xx[:, None], t[:, None], proj)

    g = np.asarray(jax.grad(f)(x))
    eps = 1e-3
    for i in [0, 3, 7]:
        xp = x.at[i].add(eps)
        xm = x.at[i].add(-eps)
        fd = (float(f(xp)) - float(f(xm))) / (2 * eps)
        np.testing.assert_allclose(g[i], fd, rtol=1e-2, atol=1e-4)


def test_swgan_step_reduces_sw2():
    """~60 Adam steps on the Fig-2 toy problem must reduce the distance."""
    cfg = GenCfg(k=1, d=3, width=32, depth=3)
    built = build_swgan_step("s", cfg, batch=256, n_proj=16)
    from compile import initlib
    regm = built.meta["registry"]
    ws = [jnp.asarray(initlib.init_tensor(s.init, tuple(s.shape), regm, 4))
          for s in built.inputs if s.role == "trainable"]
    ms = [jnp.zeros_like(w) for w in ws]
    vs = [jnp.zeros_like(w) for w in ws]
    fn = jax.jit(built.fn)
    t = jnp.float32(0.0)
    losses = []
    for i in range(60):
        alpha = jnp.asarray(rng.uniform_f32(100 + i, 256 * 1, -1, 1).reshape(256, 1))
        target = _cloud(200 + i, 256, 3)
        proj = jnp.asarray(rng.normal_f32(300 + i, 3 * 16).reshape(3, 16))
        out = fn(*ws, *ms, *vs, t, jnp.float32(0.003), alpha, target, proj)
        ws, ms, vs = list(out[:3]), list(out[3:6]), list(out[6:9])
        t = out[9]
        losses.append(float(out[10]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.9
