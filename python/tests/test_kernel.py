"""L1 correctness: the Pallas generator kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel layer: hypothesis sweeps
shapes, block sizes, frequencies and β laws; every case must match
``generator3_ref`` to f32 tolerance, and the custom VJP must match the
oracle's gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import genutil, rng
from compile.kernels.generator import generator3_pallas, vmem_bytes
from compile.kernels.ref import generator3_ref


def _mk(n, k, h, d, seed=0):
    cfg = genutil.GenCfg(k=k, d=d, width=h, depth=3)
    ws = [jnp.asarray(w) for w in genutil.make_weights(cfg, seed)]
    alpha = jnp.asarray(
        rng.normal_f32(rng.substream(seed, rng.TAG_ALPHA), n * k).reshape(n, k))
    beta = jnp.asarray(rng.uniform_f32(seed + 1, n, -2.0, 2.0))
    return alpha, beta, ws


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 40),
    k=st.integers(1, 12),
    h=st.integers(2, 48),
    d=st.integers(2, 96),
    block_n=st.sampled_from([1, 4, 16, 64]),
    freq=st.sampled_from([1.0, 4.5, 32.0]),
)
def test_kernel_matches_ref(n, k, h, d, block_n, freq):
    alpha, beta, ws = _mk(n, k, h, d, seed=n * 1000 + k)
    ref = generator3_ref(alpha, beta, *ws, freq=freq)
    out = generator3_pallas(alpha, beta, *ws, freq=freq, block_n=block_n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 17), d=st.integers(3, 50))
def test_output_on_sphere(n, d):
    """‖φ(α)‖ = |β| after normalization — the manifold constraint."""
    alpha, beta, ws = _mk(n, 5, 16, d, seed=d)
    out = np.asarray(generator3_pallas(alpha, beta, *ws, freq=4.5))
    np.testing.assert_allclose(np.linalg.norm(out, axis=1),
                               np.abs(np.asarray(beta)), rtol=1e-4, atol=1e-5)


def test_unnormalized_variant():
    alpha, beta, ws = _mk(6, 3, 8, 12)
    ref = generator3_ref(alpha, beta, *ws, freq=2.0, normalize=False)
    out = generator3_pallas(alpha, beta, *ws, freq=2.0, normalize=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_beta_scales_linearly():
    alpha, beta, ws = _mk(5, 4, 8, 16)
    one = generator3_pallas(alpha, jnp.ones_like(beta), *ws, freq=4.5)
    three = generator3_pallas(alpha, 3.0 * jnp.ones_like(beta), *ws, freq=4.5)
    np.testing.assert_allclose(np.asarray(three), 3.0 * np.asarray(one),
                               rtol=1e-5, atol=1e-6)


def test_zero_alpha_zero_beta_gives_zero():
    """The zero-init guarantee: α=0, β=0 ⇒ Δθ = 0 exactly."""
    _, _, ws = _mk(4, 9, 16, 32)
    out = generator3_pallas(jnp.zeros((4, 9)), jnp.zeros((4,)), *ws, freq=4.5)
    assert np.all(np.asarray(out) == 0.0)


def test_grad_matches_ref():
    alpha, beta, ws = _mk(7, 4, 12, 20)

    def loss_k(a, b):
        return jnp.sum(generator3_pallas(a, b, *ws, freq=4.5) ** 2)

    def loss_r(a, b):
        return jnp.sum(generator3_ref(a, b, *ws, freq=4.5) ** 2)

    ga_k, gb_k = jax.grad(loss_k, argnums=(0, 1))(alpha, beta)
    ga_r, gb_r = jax.grad(loss_r, argnums=(0, 1))(alpha, beta)
    np.testing.assert_allclose(np.asarray(ga_k), np.asarray(ga_r), rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(gb_k), np.asarray(gb_r), rtol=1e-4,
                               atol=1e-6)


def test_grad_nonzero_at_zero_alpha():
    """Training config (normalize=False, β=1): ∂/∂α ≠ 0 at the zero init —
    the paper's zero-init point is a usable starting point, not a saddle."""
    _, _, ws = _mk(3, 4, 12, 20)
    alpha = jnp.zeros((3, 4))
    beta = jnp.ones((3,))

    def loss(a):
        out = generator3_pallas(a, beta, *ws, freq=4.5, normalize=False)
        return jnp.sum(out * jnp.arange(out.size).reshape(out.shape))

    g = np.asarray(jax.grad(loss)(alpha))
    assert np.isfinite(g).all()
    assert np.abs(g).max() > 0


def test_normalized_grad_nan_at_zero_documented():
    """The exactly-normalized variant is 0/0 at α=0 — this pins WHY the
    training default is normalize=False (DESIGN.md §6)."""
    _, _, ws = _mk(2, 3, 8, 10)

    def loss(a):
        return jnp.sum(generator3_pallas(a, jnp.ones(2), *ws, freq=4.5,
                                         normalize=True))

    g = np.asarray(jax.grad(loss)(jnp.zeros((2, 3))))
    assert not np.isfinite(g).all()


def test_kernel_inside_jit():
    alpha, beta, ws = _mk(9, 5, 8, 24)
    f = jax.jit(lambda a, b: generator3_pallas(a, b, *ws, freq=4.5))
    np.testing.assert_allclose(np.asarray(f(alpha, beta)),
                               np.asarray(generator3_ref(alpha, beta, *ws, freq=4.5)),
                               rtol=1e-5, atol=1e-6)


def test_shape_validation():
    alpha, beta, ws = _mk(3, 4, 8, 16)
    with pytest.raises(ValueError):
        generator3_pallas(alpha, beta, ws[0], ws[2], ws[1], freq=1.0)


def test_vmem_estimate_default_cfg():
    """DESIGN.md §Hardware-Adaptation numbers: paper-default generator at
    block_n=128 must not fit 16 MiB without d-tiling, and the d-tiled
    footprint quoted in the doc must."""
    full = vmem_bytes(k=9, h=1000, d=5000, block_n=128)
    assert full > 16 * 2**20
    small = vmem_bytes(k=9, h=256, d=5000, block_n=64)
    assert small < 16 * 2**20
