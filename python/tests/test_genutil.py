"""Generator config / weight-law tests (Tables 5, 14, 15, 16 substrate)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import genutil, rng
from compile.genutil import GenCfg


def test_layer_shapes_depths():
    assert GenCfg(k=9, d=5000, width=1000, depth=3).layer_shapes() == [
        (9, 1000), (1000, 1000), (1000, 5000)]
    assert GenCfg(k=2, d=7, width=4, depth=2).layer_shapes() == [(2, 4), (4, 7)]
    with pytest.raises(ValueError):
        GenCfg(depth=1).layer_shapes()


def test_flops_per_chunk_paper_llama_shapes():
    """Appendix A.6: the 5→32→32→5000 generator costs 2·(5·32+32·32+32·5000)
    per forward pass (+ d for the β scale, our convention)."""
    cfg = GenCfg(k=5, d=5000, width=32, depth=3)
    assert cfg.flops_per_chunk() == 2 * (5 * 32 + 32 * 32 + 32 * 5000) + 5000


def test_make_weights_bounds_and_determinism():
    cfg = GenCfg(k=3, d=20, width=8, depth=3)
    ws = genutil.make_weights(cfg, 77)
    ws2 = genutil.make_weights(cfg, 77)
    ws3 = genutil.make_weights(cfg, 78)
    for w, w2, w3, (fi, fo) in zip(ws, ws2, ws3, cfg.layer_shapes()):
        assert w.shape == (fi, fo)
        assert np.array_equal(w, w2)
        assert not np.array_equal(w, w3)
        assert np.abs(w).max() <= 1.0 / fi + 1e-7


def test_make_weights_normal_variance_matched():
    cfg = GenCfg(k=64, d=512, width=256, depth=3, init="normal", init_scale=1.0)
    cfg_u = GenCfg(k=64, d=512, width=256, depth=3)
    wn = genutil.make_weights(cfg, 5)[1]
    wu = genutil.make_weights(cfg_u, 5)[1]
    # same variance law: Var = 1/(3·fan_in²)
    assert abs(wn.std() / wu.std() - 1.0) < 0.05


@settings(max_examples=15, deadline=None)
@given(act=st.sampled_from(["sine", "sigmoid", "relu", "lrelu", "elu", "linear"]),
       depth=st.integers(2, 5), residual=st.booleans())
def test_generator_ref_all_configs_finite(act, depth, residual):
    cfg = GenCfg(k=4, d=16, width=8, depth=depth, act=act, residual=residual,
                 normalize=True)
    ws = [jnp.asarray(w) for w in genutil.make_weights(cfg, 1)]
    alpha = jnp.asarray(rng.normal_f32(2, 6 * 4).reshape(6, 4))
    out = np.asarray(genutil.generator_ref(cfg, ws, alpha, jnp.ones(6)))
    assert out.shape == (6, 16)
    assert np.isfinite(out).all()
    # normalized output ⇒ unit rows — except rows a dead ReLU zeroed out,
    # which stay at 0 (the eps in the normalizer keeps them finite).
    norms = np.linalg.norm(out, axis=1)
    assert np.all((np.abs(norms - 1.0) < 5e-3) | (norms < 1e-6))


def test_residual_changes_output():
    base = GenCfg(k=4, d=16, width=8, depth=4)
    res = GenCfg(k=4, d=16, width=8, depth=4, residual=True)
    ws = [jnp.asarray(w) for w in genutil.make_weights(base, 3)]
    alpha = jnp.asarray(rng.normal_f32(4, 5 * 4).reshape(5, 4))
    a = np.asarray(genutil.generator_ref(base, ws, alpha, jnp.ones(5)))
    b = np.asarray(genutil.generator_ref(res, ws, alpha, jnp.ones(5)))
    assert not np.allclose(a, b)


def test_freq_override_traced():
    cfg = GenCfg(k=2, d=8, width=4, depth=3, freq=4.5)
    ws = [jnp.asarray(w) for w in genutil.make_weights(cfg, 9)]
    alpha = jnp.asarray(rng.normal_f32(1, 3 * 2).reshape(3, 2))
    a = genutil.generator_ref(cfg, ws, alpha, jnp.ones(3))
    b = genutil.generator_ref(cfg, ws, alpha, jnp.ones(3),
                              freq=jnp.float32(4.5))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
