"""Manifest contract tests (run after `make artifacts`; skipped otherwise).

The Rust runtime is entirely manifest-driven — these tests pin the schema
and the invariants it assumes.
"""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MAN = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MAN), reason="artifacts not built (run `make artifacts`)")


@pytest.fixture(scope="module")
def manifest():
    with open(MAN) as f:
        return json.load(f)


def test_every_entry_has_hlo_file(manifest):
    for name, e in manifest["entries"].items():
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 100, name


def test_input_specs_wellformed(manifest):
    for name, e in manifest["entries"].items():
        for spec in e["inputs"]:
            assert spec["dtype"] in ("f32", "i32"), name
            assert spec["role"] in ("static", "trainable", "opt", "hyper", "data")
            assert all(isinstance(d, int) and d > 0 for d in spec["shape"]), name
            if spec["role"] in ("static", "trainable"):
                assert spec["init"] is not None, f"{name}:{spec['name']}"


def test_train_step_convention(manifest):
    for name, e in manifest["entries"].items():
        if e["meta"]["kind"] != "train_step":
            continue
        roles = [s["role"] for s in e["inputs"]]
        nt = roles.count("trainable")
        assert roles.count("opt") == 2 * nt, name
        assert [s["name"] for s in e["inputs"][-4:]] == ["t", "lr", "x", "y"], name
        # outputs: trainables, m, v, t, loss, acc (+ importance for dense)
        outs = [o["name"] for o in e["outputs"]]
        assert outs[3 * nt: 3 * nt + 3] == ["t", "loss", "acc"], name
        # every trainable's output shape matches its input shape
        tr_in = [s for s in e["inputs"] if s["role"] == "trainable"]
        for s, o in zip(tr_in, e["outputs"][:nt]):
            assert s["name"] == o["name"] and s["shape"] == o["shape"], name


def test_rate_accounting(manifest):
    for name, e in manifest["entries"].items():
        meta = e["meta"]
        if meta.get("rate") and meta["kind"] == "train_step":
            if meta["method"] != "dense":
                assert 0 < meta["rate"] <= 1.2, name
                assert meta["trainable_comp"] > 0, name


def test_vit_table1_rates(manifest):
    """The Table-1 sweep must hit its advertised compression points."""
    for pct in [50, 20, 10, 5, 2, 1]:
        e = manifest["entries"].get(f"vit_mcnc{pct}_train")
        assert e is not None
        got = e["meta"]["rate"] * 100
        assert abs(got - pct) / pct < 0.15, f"{pct}%: got {got:.2f}%"


def test_paper_required_entries_present(manifest):
    required = [
        "mlp_mcnc02_train", "mlp_dense_train", "gen_mlp02_fwd",
        "vit_dense_train", "vit_mcnc1_train",
        "r20c10_mcnc1_train", "r20c10_nola_train", "r20c10_pranc1_train",
        "r20c10_mcnc5k_train", "r56c10_mcnc5k_train",
        "lm_dense_train", "lm_lora8_train", "lm_nola8_train",
        "lm_mcnclora8_train", "gen_adapter_fwd",
        "swgan_k1d3", "swgan_r20gen",
        "mlp_mcnc02_freqin_train", "mlp_mcnc02_sigmoid_train",
    ]
    for r in required:
        assert r in manifest["entries"], r


def test_groups_cover_paper_tables(manifest):
    groups = {e["group"] for e in manifest["entries"].values()}
    assert {"core", "abl_act", "abl_freq", "abl_scale", "abl_kd", "abl_width",
            "abl_depth", "vit", "resnet", "resnet_t3", "lm", "sphere"} <= groups
