"""Model-zoo shape/finiteness tests + registry invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models, rng
from compile.methods import Registry


def _init_params(model, seed=0):
    p = {}
    for i, leaf in enumerate(model.leaves()):
        s = rng.substream(seed, 1000 + i)
        if leaf.dist == "zeros":
            v = np.zeros(leaf.size, np.float32)
        elif leaf.dist == "ones":
            v = np.ones(leaf.size, np.float32)
        elif leaf.dist == "sym_uniform":
            v = rng.symmetric_f32(s, leaf.size, leaf.param)
        else:
            v = rng.normal_f32(s, leaf.size, leaf.param)
        p[leaf.name] = jnp.asarray(v.reshape(leaf.shape))
    return p


ALL_MODELS = [
    models.MlpCfg(hidden=32),
    models.ResNetCfg(blocks_per_stage=2, num_classes=10),
    models.ResNetCfg(blocks_per_stage=2, num_classes=100),
    models.ViTCfg(dim=32, depth=2, heads=2),
    models.LmCfg(vocab=64, dim=32, depth=2, heads=2, seq=16),
]


@pytest.mark.parametrize("model", ALL_MODELS, ids=lambda m: m.name)
def test_apply_shapes_and_finite(model):
    b = 4
    xs, ys = model.data_shapes(b)
    if getattr(model, "data_dtype", "f32") == "i32":
        x = jnp.asarray((rng.uniform_f32(1, int(np.prod(xs)), 0, model.vocab)
                         ).astype(np.int32).reshape(xs))
        y = jnp.asarray((rng.uniform_f32(2, int(np.prod(ys)), 0, model.vocab)
                         ).astype(np.int32).reshape(ys))
    else:
        x = jnp.asarray(rng.normal_f32(1, int(np.prod(xs))).reshape(xs))
        ncls = model.num_classes if hasattr(model, "num_classes") else model.out_dim
        y = jnp.asarray((rng.uniform_f32(2, int(np.prod(ys)), 0, ncls)
                         ).astype(np.int32).reshape(ys))
    p = _init_params(model)
    loss, acc = model.loss_and_acc(p, x, y)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(acc) <= 1.0


@pytest.mark.parametrize("model", ALL_MODELS, ids=lambda m: m.name)
def test_leaf_names_unique_and_sizes(model):
    leaves = model.leaves()
    names = [l.name for l in leaves]
    assert len(names) == len(set(names))
    reg = Registry(leaves)
    assert reg.Dc + reg.R == sum(l.size for l in leaves)
    # registry offsets tile [0, Dc) and [0, R) exactly once
    comp_cover = sorted((off, off + l.size) for l, off in reg.comp)
    pos = 0
    for a, b in comp_cover:
        assert a == pos
        pos = b
    assert pos == reg.Dc


@pytest.mark.parametrize("model", ALL_MODELS, ids=lambda m: m.name)
def test_grads_flow_everywhere(model):
    """No dead parameters: every leaf receives nonzero gradient signal."""
    b = 4
    xs, ys = model.data_shapes(b)
    if getattr(model, "data_dtype", "f32") == "i32":
        x = jnp.asarray((rng.uniform_f32(3, int(np.prod(xs)), 0, model.vocab)
                         ).astype(np.int32).reshape(xs))
        y = jnp.asarray((rng.uniform_f32(4, int(np.prod(ys)), 0, model.vocab)
                         ).astype(np.int32).reshape(ys))
    else:
        x = jnp.asarray(rng.normal_f32(3, int(np.prod(xs))).reshape(xs))
        ncls = model.num_classes if hasattr(model, "num_classes") else model.out_dim
        y = jnp.asarray((rng.uniform_f32(4, int(np.prod(ys)), 0, ncls)
                         ).astype(np.int32).reshape(ys))
    p = _init_params(model)
    g = jax.grad(lambda pp: model.loss_and_acc(pp, x, y)[0])(p)
    dead = [k for k, v in g.items()
            if not np.isfinite(np.asarray(v)).all() or np.abs(np.asarray(v)).sum() == 0]
    # positional embeddings past the sequence length legitimately get no grad
    dead = [k for k in dead if k != "wpe"]
    assert dead == [], f"dead/nan gradients: {dead}"


def test_resnet_depth_names():
    assert models.ResNetCfg(3, num_classes=10).name == "resnet20c10"
    assert models.ResNetCfg(9, num_classes=100).name == "resnet56c100"


def test_vit_token_count():
    v = models.ViTCfg()
    assert v.n_tokens == 65
    assert v.patch_dim == 48


def test_lm_causality():
    """Future tokens must not influence earlier logits."""
    lm = models.LmCfg(vocab=32, dim=16, depth=1, heads=2, seq=8)
    p = _init_params(lm)
    x1 = jnp.asarray(np.arange(8, dtype=np.int32)[None, :] % 32)
    x2 = x1.at[0, -1].set(31)  # change only the last token
    l1 = np.asarray(lm.apply(p, x1))
    l2 = np.asarray(lm.apply(p, x2))
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], atol=1e-5)
    assert not np.allclose(l1[0, -1], l2[0, -1])
