"""Method-layer tests: registries, materialization, and live train steps.

The train-step tests execute the *same functions that get AOT-lowered*
(with inits from ``initlib`` — i.e. exactly what the Rust coordinator will
feed) and assert the loss actually decreases. This pins the full L2
semantics before anything crosses the PJRT boundary.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import initlib, models, rng
from compile.genutil import GenCfg
from compile.methods import (Dense, Lora, Mcnc, McncLora, NolaLora, Registry,
                             build_eval_step, build_reconstruct,
                             build_train_step, chunk_for_rate)

MLP = models.MlpCfg(hidden=32)
REG = Registry(MLP.leaves())
GEN = GenCfg(k=5, d=500, width=32)


def _data(batch, seed=0, model=MLP):
    xs, ys = model.data_shapes(batch)
    x = rng.normal_f32(rng.substream(seed, rng.TAG_DATA), int(np.prod(xs)))
    # make a learnable synthetic task: class = argmax of 10 fixed projections
    x = x.reshape(xs)
    w = rng.normal_f32(99, xs[1] * 10).reshape(xs[1], 10)
    y = np.argmax(x @ w, axis=1).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


def _initial_args(built, seed=7):
    regm = built.meta["registry"]
    vals = []
    for spec in built.inputs:
        if spec.role in ("static", "trainable"):
            v = initlib.init_tensor(spec.init, tuple(spec.shape), regm, seed)
            vals.append(jnp.asarray(v.reshape(spec.shape)))
        elif spec.role == "opt":
            vals.append(jnp.zeros(spec.shape, jnp.float32))
        else:
            vals.append(None)  # hyper/data filled by caller
    return vals


def _run_steps(built, steps, lr, batch, model=MLP, seed=7):
    args = _initial_args(built, seed)
    ns = sum(1 for s in built.inputs if s.role == "static")
    nt = sum(1 for s in built.inputs if s.role == "trainable")
    fn = jax.jit(built.fn)
    t = jnp.float32(0.0)
    losses = []
    for i in range(steps):
        x, y = _data(batch, seed=i % 4)
        full = args[: ns + 3 * nt] + [t, jnp.float32(lr), x, y]
        out = fn(*full)
        new_state = list(out[: 3 * nt])
        args = args[:ns] + new_state
        t = out[3 * nt]
        losses.append(float(out[3 * nt + 1]))
    return losses, args


METHODS = {
    "dense": lambda: Dense(REG),
    "mcnc": lambda: Mcnc(REG, GEN),
    "pranc": lambda: Mcnc(REG, GenCfg(k=5, d=500, width=32, act="linear",
                                      normalize=False), name="pranc"),
    "lora": lambda: Lora(REG, 4),
    "mcnc_lora": lambda: McncLora(REG, 4, GenCfg(k=5, d=256, width=32)),
    "nola": lambda: NolaLora(REG, 4, 8),
}


@pytest.mark.parametrize("name", list(METHODS))
def test_train_step_learns(name):
    """Every method's lowered-identical step must reduce the loss. The
    reparameterized methods move slower per step (the paper trains them
    5-10× longer with 5-10× the lr), so the bar here is directional."""
    method = METHODS[name]()
    built = build_train_step(f"t_{name}", MLP, method, batch=64)
    slow = name in ("mcnc", "pranc", "mcnc_lora", "nola")
    lr = 0.05 if slow else 0.005
    losses, _ = _run_steps(built, steps=60 if slow else 30, lr=lr, batch=64)
    assert all(np.isfinite(losses))
    drop = losses[0] - min(losses[-10:])
    assert drop > 0.05, f"{name}: no learning: {losses[:3]}…{losses[-3:]}"


@pytest.mark.parametrize("name", list(METHODS))
def test_zero_init_matches_theta0(name):
    """At t=0 the materialized params must equal θ0 (+ raw init): the
    compressed delta starts at exactly zero for every method."""
    method = METHODS[name]()
    built = build_reconstruct(f"r_{name}", MLP, method)
    args = _initial_args(built, seed=3)
    theta = np.asarray(built.fn(*args)[0])
    regm = built.meta["registry"]
    if name == "dense":
        expect = initlib.init_tensor({"kind": "comp_leaves"}, (REG.Dc,), regm, 3)
    else:
        expect = initlib.init_tensor({"kind": "comp_leaves"}, (REG.Dc,), regm, 3)
    np.testing.assert_allclose(theta, expect, atol=1e-6)


def test_eval_step_consistent_with_train_loss():
    method = METHODS["mcnc"]()
    tb = build_train_step("t", MLP, method, batch=64)
    eb = build_eval_step("e", MLP, method, batch=64)
    _, args = _run_steps(tb, steps=5, lr=0.02, batch=64)
    ns = sum(1 for s in tb.inputs if s.role == "static")
    nt = sum(1 for s in tb.inputs if s.role == "trainable")
    x, y = _data(64, seed=0)
    loss_e, acc_e = jax.jit(eb.fn)(*(args[: ns + nt] + [x, y]))
    # one more "train" call on same batch reports the pre-update loss
    t = jnp.float32(5.0)
    out = jax.jit(tb.fn)(*(args[: ns + 3 * nt] + [t, jnp.float32(0.0), x, y]))
    np.testing.assert_allclose(float(loss_e), float(out[3 * nt + 1]), rtol=1e-4)


def test_dense_importance_and_mask():
    method = Dense(REG)
    built = build_train_step("t", MLP, method, batch=32)
    args = _initial_args(built)
    ns, nt = 1, 2
    x, y = _data(32)
    out = jax.jit(built.fn)(*(args[: ns + 3 * nt] + [jnp.float32(0), jnp.float32(0.01), x, y]))
    imp = np.asarray(out[-1])
    assert imp.shape == (REG.Dc,)
    assert (imp >= 0).all() and imp.max() > 0
    # zero mask ⇒ all compressed weights dead ⇒ importance identically 0
    args[0] = jnp.zeros_like(args[0])
    out0 = jax.jit(built.fn)(*(args[: ns + 3 * nt] + [jnp.float32(0), jnp.float32(0.01), x, y]))
    assert np.abs(np.asarray(out0[-1])).max() == 0.0


@settings(max_examples=30, deadline=None)
@given(dc=st.integers(100, 10_000_000), rate=st.floats(0.001, 0.9),
       k=st.integers(1, 64))
def test_chunk_for_rate_properties(dc, rate, k):
    d, n = chunk_for_rate(dc, rate, k)
    assert d >= k + 1
    assert n * d >= dc  # chunks cover the vector
    assert (n - 1) * d < dc  # no fully-wasted chunk
    achieved = n * (k + 1) / dc
    # achieved rate within 2x of request (graininess at tiny dc is expected)
    assert achieved <= max(rate * 2.0, (k + 1) / dc * 1.01 + 1e-9) or dc < (k + 1) / rate


def test_mcnc_budget_accounting():
    m = Mcnc(REG, GEN)
    meta = m.meta()
    assert meta["trainable_comp"] == m.n * (GEN.k + 1)
    assert meta["n_chunks"] == math.ceil(REG.Dc / GEN.d)
    assert meta["recon_flops"] == m.n * GEN.flops_per_chunk()


def test_lora_delta_manual():
    """LoRA materialization equals a hand-built A@B update on one target."""
    method = Lora(REG, 2)
    built = build_reconstruct("r", MLP, method)
    args = _initial_args(built, seed=11)
    names = [s.name for s in built.inputs]
    a_flat = np.asarray(args[names.index("lora_a")]).copy()
    b_flat = np.array(args[names.index("lora_b")]).copy()
    b_flat[:] = 0.0
    b_flat[: 2 * 32] = 0.5  # first target w1: B slice is [r*b] = [2*32]
    args[names.index("lora_b")] = jnp.asarray(b_flat)
    theta = np.asarray(built.fn(*args)[0])
    theta0 = initlib.init_tensor({"kind": "comp_leaves"}, (REG.Dc,),
                                 built.meta["registry"], 11)
    first = REG.comp[0][0]
    a, b = first.lora
    A = a_flat[: a * 2].reshape(a, 2)
    B = b_flat[: 2 * b].reshape(2, b)
    expect = theta0[: first.size] + (A @ B).reshape(-1)
    np.testing.assert_allclose(theta[: first.size], expect, rtol=1e-5, atol=1e-6)
    # untouched targets: delta == 0
    np.testing.assert_allclose(theta[first.size:], theta0[first.size:], atol=1e-6)


def test_nola_budget_matching():
    n = NolaLora(REG, 4, 16)
    meta = n.meta()
    assert meta["trainable_comp"] == 2 * len(REG.lora_targets) * 16
    assert meta["recon_flops"] == 2 * 16 * (n.Da + n.Db)


def test_train_step_input_convention():
    """Manifest ordering contract the Rust runtime relies on."""
    built = build_train_step("t", MLP, METHODS["mcnc"](), batch=8)
    roles = [s.role for s in built.inputs]
    ns = roles.count("static")
    nt = roles.count("trainable")
    assert roles == (["static"] * ns + ["trainable"] * nt + ["opt"] * 2 * nt
                     + ["hyper", "hyper", "data", "data"])
    assert [s.name for s in built.inputs[-4:]] == ["t", "lr", "x", "y"]
