"""L2 — reparameterization methods and AOT-able train/eval step builders.

Each *method* (dense, MCNC, PRANC, LoRA, MCNC-LoRA, NOLA-LoRA) defines
  * ``statics``     — frozen inputs (θ0, generator weights, random bases …),
  * ``trainables``  — the optimized state (the compressed representation),
  * ``materialize`` — how statics + trainables become the model's params.

Every tensor spec carries an *init law* (dict) so the Rust coordinator can
synthesize the exact initial value from a scalar seed via the shared
SplitMix64 streams (``initlib.py`` is the Python twin used in tests). The
step functions keep Adam entirely inside the graph; the only things crossing
the PJRT boundary each step are the data batch and scalar hyperparameters.

Positional input convention (recorded per-executable in the manifest):
    train_step : [*statics, *trainables, *adam_m, *adam_v, t, lr, x, y]
               → [*trainables', *adam_m', *adam_v', t', loss, acc (, imp)]
    eval_step  : [*statics, *trainables, x, y] → [loss, acc]
    predict    : [*statics, *trainables, x] → [logits]
    reconstruct: [*statics, *trainables] → [theta_c]
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from . import genutil
from .genutil import GenCfg
from .kernels.generator import generator3_pallas

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


# --------------------------------------------------------------------------
# Tensor specs + registry
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class TensorSpec:
    name: str
    shape: tuple
    dtype: str = "f32"  # f32 | i32
    role: str = "static"  # static | trainable | data | hyper
    init: dict | None = None  # init law for the Rust coordinator

    @property
    def size(self):
        n = 1
        for s in self.shape:
            n *= s
        return n

    def to_meta(self):
        return {"name": self.name, "shape": list(self.shape),
                "dtype": self.dtype, "role": self.role, "init": self.init}


class Registry:
    """Flattened layout of a model's leaves: compressed part + raw part."""

    def __init__(self, leaves):
        self.leaves = leaves
        self.comp, self.raw = [], []
        dc = r = 0
        for leaf in leaves:
            if leaf.compress:
                self.comp.append((leaf, dc))
                dc += leaf.size
            else:
                self.raw.append((leaf, r))
                r += leaf.size
        self.Dc, self.R = dc, r
        self.lora_targets = [(leaf, off) for leaf, off in self.comp if leaf.lora]

    def unflatten(self, theta_c, raw_vec):
        p = {}
        for leaf, off in self.comp:
            p[leaf.name] = jax.lax.dynamic_slice_in_dim(theta_c, off, leaf.size).reshape(leaf.shape)
        for leaf, off in self.raw:
            p[leaf.name] = jax.lax.dynamic_slice_in_dim(raw_vec, off, leaf.size).reshape(leaf.shape)
        return p

    def lora_dims(self, rank):
        """[(leaf, a, b, a_off, b_off)] with offsets into A_flat / B_flat."""
        out, ao, bo = [], 0, 0
        for leaf, _ in self.lora_targets:
            a, b = leaf.lora
            out.append((leaf, a, b, ao, bo))
            ao += a * rank
            bo += rank * b
        return out, ao, bo

    def to_meta(self):
        return {"Dc": self.Dc, "R": self.R,
                "leaves": [l.to_meta() for l in self.leaves]}


def chunk_for_rate(Dc: int, rate: float, k: int) -> tuple[int, int]:
    """Pick chunk size d and count n so n·(k+1) ≈ rate·Dc (paper §3.3)."""
    d = max(int(math.ceil((k + 1) / rate)), k + 1)
    n = int(math.ceil(Dc / d))
    return d, n


# --------------------------------------------------------------------------
# Methods
# --------------------------------------------------------------------------

class Dense:
    """Uncompressed baseline. The multiplicative ``mask`` static turns it
    into the magnitude / PLATON-lite pruning substrate: Rust recomputes the
    mask between steps from the ``importance`` output (|θ·∇θ|)."""

    name = "dense"
    emit_importance = True

    def __init__(self, reg: Registry):
        self.reg = reg

    def statics(self):
        return [TensorSpec("mask", (self.reg.Dc,), init={"kind": "ones"})]

    def trainables(self):
        return [
            TensorSpec("theta_c", (self.reg.Dc,), role="trainable",
                       init={"kind": "comp_leaves"}),
            TensorSpec("raw", (max(self.reg.R, 1),), role="trainable",
                       init={"kind": "raw_leaves"}),
        ]

    def materialize(self, st, tr):
        return self.reg.unflatten(tr["theta_c"] * st["mask"], tr["raw"])

    def reconstruct(self, st, tr):
        return tr["theta_c"] * st["mask"]

    def meta(self):
        return {"method": "dense", "trainable_comp": self.reg.Dc,
                "rate": 1.0}


class Mcnc:
    """The paper's contribution: per-chunk Δθ = β·φ(α) on S^{d-1}.

    ``act='linear', normalize=False`` recovers a chunked PRANC (the paper's
    Table 5 "None" row); that alias is exported as method name "pranc".
    """

    name = "mcnc"

    def __init__(self, reg: Registry, gen: GenCfg, beta_init: float = 1.0,
                 name: str = "mcnc", use_pallas: bool = True,
                 freq_input: bool = False):
        self.reg, self.gen, self.beta_init = reg, gen, beta_init
        self.name = name
        self.freq_input = freq_input
        self.n = int(math.ceil(reg.Dc / gen.d))
        self.use_pallas = (use_pallas and gen.depth == 3 and gen.act == "sine"
                           and not gen.residual and not freq_input)

    def statics(self):
        specs = [TensorSpec("theta0_c", (self.reg.Dc,), init={"kind": "comp_leaves"})]
        for i, (a, b) in enumerate(self.gen.layer_shapes()):
            specs.append(TensorSpec(f"gw{i}", (a, b),
                                    init={"kind": "gen_layer", "layer": i,
                                          "gen": self.gen.to_meta()}))
        if self.freq_input:
            specs.append(TensorSpec("freq", (), init={"kind": "ones"}))
        return specs

    def trainables(self):
        return [
            TensorSpec("alpha", (self.n, self.gen.k), role="trainable",
                       init={"kind": "zeros"}),
            TensorSpec("beta", (self.n,), role="trainable",
                       init={"kind": "ones"} if self.beta_init == 1.0
                       else {"kind": "zeros"}),
            TensorSpec("raw", (max(self.reg.R, 1),), role="trainable",
                       init={"kind": "raw_leaves"}),
        ]

    def delta(self, st, tr):
        ws = [st[f"gw{i}"] for i in range(self.gen.depth)]
        if self.use_pallas:
            out = generator3_pallas(tr["alpha"], tr["beta"], *ws,
                                    freq=self.gen.freq,
                                    normalize=self.gen.normalize)
        else:
            out = genutil.generator_ref(self.gen, ws, tr["alpha"], tr["beta"],
                                        freq=st.get("freq"))
        return out.reshape(-1)[: self.reg.Dc]

    def materialize(self, st, tr):
        return self.reg.unflatten(st["theta0_c"] + self.delta(st, tr), tr["raw"])

    def reconstruct(self, st, tr):
        return st["theta0_c"] + self.delta(st, tr)

    def meta(self):
        tc = self.n * (self.gen.k + 1)
        return {"method": self.name, "gen": self.gen.to_meta(),
                "n_chunks": self.n, "trainable_comp": tc,
                "rate": tc / self.reg.Dc,
                "recon_flops": self.n * self.gen.flops_per_chunk()}


def _lora_delta_c(reg: Registry, rank: int, a_flat, b_flat, scale: float):
    """Assemble the compressed-flat delta from per-target A@B low-rank updates."""
    dims, _, _ = reg.lora_dims(rank)
    by_name = {leaf.name: (leaf, a, b, ao, bo) for leaf, a, b, ao, bo in dims}
    pieces = []
    for leaf, _ in reg.comp:
        if leaf.name in by_name:
            _, a, b, ao, bo = by_name[leaf.name]
            A = jax.lax.dynamic_slice_in_dim(a_flat, ao, a * rank).reshape(a, rank)
            B = jax.lax.dynamic_slice_in_dim(b_flat, bo, rank * b).reshape(rank, b)
            pieces.append(((A @ B) * scale).reshape(-1))
        else:
            pieces.append(jnp.zeros((leaf.size,), jnp.float32))
    return jnp.concatenate(pieces)


class Lora:
    """Classic LoRA(r) on every matrix-shaped compressed leaf."""

    name = "lora"

    def __init__(self, reg: Registry, rank: int, scale: float = 1.0):
        self.reg, self.rank, self.scale = reg, rank, scale
        _, self.Da, self.Db = reg.lora_dims(rank)

    def statics(self):
        return [TensorSpec("theta0_c", (self.reg.Dc,), init={"kind": "comp_leaves"})]

    def trainables(self):
        return [
            TensorSpec("lora_a", (self.Da,), role="trainable",
                       init={"kind": "lora_a", "rank": self.rank}),
            TensorSpec("lora_b", (self.Db,), role="trainable",
                       init={"kind": "zeros"}),
            TensorSpec("raw", (max(self.reg.R, 1),), role="trainable",
                       init={"kind": "raw_leaves"}),
        ]

    def materialize(self, st, tr):
        d = _lora_delta_c(self.reg, self.rank, tr["lora_a"], tr["lora_b"], self.scale)
        return self.reg.unflatten(st["theta0_c"] + d, tr["raw"])

    def reconstruct(self, st, tr):
        d = _lora_delta_c(self.reg, self.rank, tr["lora_a"], tr["lora_b"], self.scale)
        return st["theta0_c"] + d

    def meta(self):
        tc = self.Da + self.Db
        return {"method": "lora", "rank": self.rank, "trainable_comp": tc,
                "rate": tc / self.reg.Dc}


class McncLora:
    """MCNC reparameterizing the flattened LoRA factors (the paper's LLM
    setting and its best from-scratch variant, "Ours w/ LoRA")."""

    name = "mcnc_lora"

    def __init__(self, reg: Registry, rank: int, gen: GenCfg, scale: float = 1.0):
        self.reg, self.rank, self.gen, self.scale = reg, rank, gen, scale
        _, self.Da, self.Db = reg.lora_dims(rank)
        self.Dl = self.Da + self.Db
        self.n = int(math.ceil(self.Dl / gen.d))
        self.use_pallas = gen.depth == 3 and gen.act == "sine"

    def statics(self):
        specs = [
            TensorSpec("theta0_c", (self.reg.Dc,), init={"kind": "comp_leaves"}),
            # A-part random (so ∂Δ/∂B ≠ 0 at the zero-init point), B-part 0.
            TensorSpec("lora0", (self.Dl,), init={"kind": "lora0", "rank": self.rank}),
        ]
        for i, (a, b) in enumerate(self.gen.layer_shapes()):
            specs.append(TensorSpec(f"gw{i}", (a, b),
                                    init={"kind": "gen_layer", "layer": i,
                                          "gen": self.gen.to_meta()}))
        return specs

    def trainables(self):
        return [
            TensorSpec("alpha", (self.n, self.gen.k), role="trainable",
                       init={"kind": "zeros"}),
            TensorSpec("beta", (self.n,), role="trainable", init={"kind": "ones"}),
            TensorSpec("raw", (max(self.reg.R, 1),), role="trainable",
                       init={"kind": "raw_leaves"}),
        ]

    def _lora_vec(self, st, tr):
        ws = [st[f"gw{i}"] for i in range(self.gen.depth)]
        if self.use_pallas:
            out = generator3_pallas(tr["alpha"], tr["beta"], *ws,
                                    freq=self.gen.freq,
                                    normalize=self.gen.normalize)
        else:
            out = genutil.generator_ref(self.gen, ws, tr["alpha"], tr["beta"])
        return st["lora0"] + out.reshape(-1)[: self.Dl]

    def _delta_c(self, st, tr):
        lv = self._lora_vec(st, tr)
        return _lora_delta_c(self.reg, self.rank, lv[: self.Da], lv[self.Da:],
                             self.scale)

    def materialize(self, st, tr):
        return self.reg.unflatten(st["theta0_c"] + self._delta_c(st, tr), tr["raw"])

    def reconstruct(self, st, tr):
        return st["theta0_c"] + self._delta_c(st, tr)

    def meta(self):
        tc = self.n * (self.gen.k + 1)
        return {"method": "mcnc_lora", "rank": self.rank, "gen": self.gen.to_meta(),
                "n_chunks": self.n, "trainable_comp": tc,
                "rate": tc / self.reg.Dc, "lora_dim": self.Dl,
                "recon_flops": self.n * self.gen.flops_per_chunk()}


class NolaLora:
    """NOLA: LoRA factors as linear combinations of m frozen random bases."""

    name = "nola"

    def __init__(self, reg: Registry, rank: int, bases: int, scale: float = 1.0):
        self.reg, self.rank, self.m, self.scale = reg, rank, bases, scale
        self.dims, self.Da, self.Db = reg.lora_dims(rank)
        self.L = len(self.dims)

    def statics(self):
        return [
            TensorSpec("theta0_c", (self.reg.Dc,), init={"kind": "comp_leaves"}),
            TensorSpec("basis_a", (self.m * self.Da,),
                       init={"kind": "nola_basis", "side": "a", "m": self.m,
                             "rank": self.rank}),
            TensorSpec("basis_b", (self.m * self.Db,),
                       init={"kind": "nola_basis", "side": "b", "m": self.m,
                             "rank": self.rank}),
        ]

    def trainables(self):
        return [
            TensorSpec("coef_a", (self.L, self.m), role="trainable",
                       init={"kind": "nola_coef", "m": self.m}),
            TensorSpec("coef_b", (self.L, self.m), role="trainable",
                       init={"kind": "zeros"}),
            TensorSpec("raw", (max(self.reg.R, 1),), role="trainable",
                       init={"kind": "raw_leaves"}),
        ]

    def _factors(self, st, tr):
        """Per-target A [a,r], B [r,b] from coefficient × basis contractions."""
        a_parts, b_parts = [], []
        for j, (leaf, a, b, ao, bo) in enumerate(self.dims):
            ba = jax.lax.dynamic_slice_in_dim(
                st["basis_a"], self.m * ao, self.m * a * self.rank
            ).reshape(self.m, a * self.rank)
            bb = jax.lax.dynamic_slice_in_dim(
                st["basis_b"], self.m * bo, self.m * self.rank * b
            ).reshape(self.m, self.rank * b)
            a_parts.append(tr["coef_a"][j] @ ba)
            b_parts.append(tr["coef_b"][j] @ bb)
        return jnp.concatenate(a_parts), jnp.concatenate(b_parts)

    def _delta_c(self, st, tr):
        af, bf = self._factors(st, tr)
        return _lora_delta_c(self.reg, self.rank, af, bf, self.scale)

    def materialize(self, st, tr):
        return self.reg.unflatten(st["theta0_c"] + self._delta_c(st, tr), tr["raw"])

    def reconstruct(self, st, tr):
        return st["theta0_c"] + self._delta_c(st, tr)

    def meta(self):
        tc = 2 * self.L * self.m
        # NOLA reconstruction: 2·m FLOPs per generated factor element.
        return {"method": "nola", "rank": self.rank, "bases": self.m,
                "trainable_comp": tc, "rate": tc / self.reg.Dc,
                "recon_flops": 2 * self.m * (self.Da + self.Db)}


# --------------------------------------------------------------------------
# Step builders
# --------------------------------------------------------------------------

def _data_specs(model, batch):
    xs, ys = model.data_shapes(batch)
    xdtype = getattr(model, "data_dtype", "f32")
    return [TensorSpec("x", xs, xdtype, "data"), TensorSpec("y", ys, "i32", "data")]


def _adam_update(p, g, m, v, t, lr):
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mh = m / (1.0 - ADAM_B1 ** t)
    vh = v / (1.0 - ADAM_B2 ** t)
    return p - lr * mh / (jnp.sqrt(vh) + ADAM_EPS), m, v


@dataclass
class Built:
    """A lowered-able executable: fn + positional specs + manifest meta."""
    name: str
    fn: object
    inputs: list
    outputs: list  # [(name, shape, dtype)]
    meta: dict


def build_train_step(name, model, method, batch: int) -> Built:
    statics, trains = method.statics(), method.trainables()
    data = _data_specs(model, batch)
    hyper = [TensorSpec("t", (), "f32", "hyper"), TensorSpec("lr", (), "f32", "hyper")]
    emit_imp = getattr(method, "emit_importance", False)

    ns, nt = len(statics), len(trains)

    def step(*args):
        st = {s.name: a for s, a in zip(statics, args[:ns])}
        tr_list = args[ns: ns + nt]
        m_list = args[ns + nt: ns + 2 * nt]
        v_list = args[ns + 2 * nt: ns + 3 * nt]
        t, lr, x, y = args[ns + 3 * nt:]

        def loss_fn(tr_tuple):
            tr = {s.name: a for s, a in zip(trains, tr_tuple)}
            params = method.materialize(st, tr)
            loss, acc = model.loss_and_acc(params, x, y)
            return loss, acc

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(tuple(tr_list))
        t1 = t + 1.0
        outs_p, outs_m, outs_v = [], [], []
        for p, g, m, v in zip(tr_list, grads, m_list, v_list):
            p1, m1, v1 = _adam_update(p, g, m, v, t1, lr)
            outs_p.append(p1)
            outs_m.append(m1)
            outs_v.append(v1)
        extra = ()
        if emit_imp:
            # PLATON-style importance for the pruning substrate: |θ·∇θ|.
            extra = (jnp.abs(tr_list[0] * grads[0]),)
        return (*outs_p, *outs_m, *outs_v, t1, loss, acc, *extra)

    inputs = (
        statics
        + trains
        + [TensorSpec(f"m_{s.name}", s.shape, s.dtype, "opt") for s in trains]
        + [TensorSpec(f"v_{s.name}", s.shape, s.dtype, "opt") for s in trains]
        + hyper
        + data
    )
    outputs = (
        [(s.name, s.shape, s.dtype) for s in trains]
        + [(f"m_{s.name}", s.shape, s.dtype) for s in trains]
        + [(f"v_{s.name}", s.shape, s.dtype) for s in trains]
        + [("t", (), "f32"), ("loss", (), "f32"), ("acc", (), "f32")]
    )
    if emit_imp:
        outputs.append(("importance", (method.reg.Dc,), "f32"))
    meta = {"kind": "train_step", "model": model.name, "batch": batch,
            "registry": method.reg.to_meta(), **method.meta()}
    return Built(name, step, inputs, outputs, meta)


def build_eval_step(name, model, method, batch: int) -> Built:
    statics, trains = method.statics(), method.trainables()
    data = _data_specs(model, batch)
    ns = len(statics)

    def evalf(*args):
        st = {s.name: a for s, a in zip(statics, args[:ns])}
        tr = {s.name: a for s, a in zip(trains, args[ns: ns + len(trains)])}
        x, y = args[ns + len(trains):]
        params = method.materialize(st, tr)
        loss, acc = model.loss_and_acc(params, x, y)
        return (loss, acc)

    inputs = statics + trains + data
    outputs = [("loss", (), "f32"), ("acc", (), "f32")]
    meta = {"kind": "eval_step", "model": model.name, "batch": batch,
            "registry": method.reg.to_meta(), **method.meta()}
    return Built(name, evalf, inputs, outputs, meta)


def build_predict(name, model, method, batch: int) -> Built:
    statics, trains = method.statics(), method.trainables()
    xs, _ = model.data_shapes(batch)
    xdtype = getattr(model, "data_dtype", "f32")
    ns = len(statics)

    def pred(*args):
        st = {s.name: a for s, a in zip(statics, args[:ns])}
        tr = {s.name: a for s, a in zip(trains, args[ns: ns + len(trains)])}
        x = args[-1]
        params = method.materialize(st, tr)
        return (model.apply(params, x),)

    inputs = statics + trains + [TensorSpec("x", xs, xdtype, "data")]
    # output shape resolved at lower time; recorded as None here
    meta = {"kind": "predict", "model": model.name, "batch": batch,
            "registry": method.reg.to_meta(), **method.meta()}
    return Built(name, pred, inputs, [("logits", None, "f32")], meta)


def build_reconstruct(name, model, method) -> Built:
    statics, trains = method.statics(), method.trainables()
    ns = len(statics)

    def rec(*args):
        st = {s.name: a for s, a in zip(statics, args[:ns])}
        tr = {s.name: a for s, a in zip(trains, args[ns:])}
        return (method.reconstruct(st, tr),)

    inputs = statics + trains
    outputs = [("theta_c", (method.reg.Dc,), "f32")]
    meta = {"kind": "reconstruct", "model": model.name,
            "registry": method.reg.to_meta(), **method.meta()}
    return Built(name, rec, inputs, outputs, meta)
