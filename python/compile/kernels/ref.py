"""Pure-jnp correctness oracle for the MCNC generator Pallas kernel.

The hot-path configuration (depth-3, sine, L2-normalized) written as plain
jnp ops. ``python/tests/test_kernel.py`` pins the Pallas kernel to this
oracle across shapes/dtypes with hypothesis; the generic-config oracle lives
in ``compile.genutil.generator_ref``.
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-8


def generator3_ref(alpha, beta, w1, w2, w3, freq: float, normalize: bool = True):
    """alpha: [n,k] f32, beta: [n] f32, w1: [k,h], w2: [h,h], w3: [h,d] → [n,d].

    u = sin(freq·α W1); u = sin(u W2); v = sin(u W3); out = β · v/‖v‖.
    """
    u = jnp.sin(jnp.float32(freq) * (alpha @ w1))
    u = jnp.sin(u @ w2)
    v = jnp.sin(u @ w3)
    if normalize:
        v = v / (jnp.linalg.norm(v, axis=-1, keepdims=True) + EPS)
    return v * beta[:, None]
