"""L1 — the MCNC generator as a Pallas kernel.

Reconstructing parameter chunks from ``(α, β)`` is the compute hot-spot of
MCNC serving (every request batch pays it when its adapter is cold), so it
is written as a single fused kernel: three matmuls + sine epilogues +
L2-normalize + β-scale, tiled over the chunk axis.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid iterates over
blocks of chunks (``block_n``) and, when ``d`` is large, over output tiles
(``block_d``); W1/W2 and the α-block stay resident in VMEM across the inner
d-tiles, W3 is streamed tile-by-tile, and all three matmuls hit the MXU with
VPU epilogues. Normalization needs the full row norm, so the d-tiled variant
accumulates squared sums in a scratch pass; the single-tile fast path
(d == block_d) normalizes in-register.

On this CPU image the kernel must run with ``interpret=True`` (real TPU
lowering emits a Mosaic custom-call the CPU PJRT client cannot execute);
interpret mode lowers to plain HLO so the same graph runs inside the AOT
train steps that the Rust runtime executes.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-8


def _kernel_fused(alpha_ref, beta_ref, w1_ref, w2_ref, w3_ref, o_ref, *,
                  freq: float, normalize: bool):
    """One grid step: reconstruct a (block_n, d) tile of chunks."""
    a = alpha_ref[...]  # (bn, k)
    u = jnp.sin(jnp.float32(freq) * jnp.dot(a, w1_ref[...]))  # (bn, h) — MXU
    u = jnp.sin(jnp.dot(u, w2_ref[...]))  # (bn, h) — MXU
    v = jnp.sin(jnp.dot(u, w3_ref[...]))  # (bn, d) — MXU
    if normalize:
        # VPU epilogue: row norms never leave VMEM. Matches the reference's
        # v / (||v|| + eps) law exactly.
        nrm = jnp.sqrt(jnp.sum(v * v, axis=-1, keepdims=True))
        v = v / (nrm + EPS)
    o_ref[...] = v * beta_ref[...][:, None]


def _generator3_pallas_raw(alpha, beta, w1, w2, w3, *, freq: float,
                           normalize: bool = True, block_n: int = 64,
                           interpret: bool = True):
    """Fused MCNC generator forward. alpha: [n,k], beta: [n] → [n,d].

    Pads the chunk axis up to a multiple of ``block_n`` (padded rows are
    sliced off afterwards — they cost one wasted grid step at most).
    """
    n, k = alpha.shape
    h = w1.shape[1]
    d = w3.shape[1]
    if w1.shape != (k, h) or w2.shape != (h, h) or w3.shape != (h, d):
        raise ValueError("generator weight shapes are inconsistent")
    bn = min(block_n, max(n, 1))
    n_pad = (-n) % bn
    if n_pad:
        alpha = jnp.pad(alpha, ((0, n_pad), (0, 0)))
        beta = jnp.pad(beta, ((0, n_pad),))
    grid = ((n + n_pad) // bn,)

    out = pl.pallas_call(
        partial(_kernel_fused, freq=freq, normalize=normalize),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((k, h), lambda i: (0, 0)),
            pl.BlockSpec((h, h), lambda i: (0, 0)),
            pl.BlockSpec((h, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, d), jnp.float32),
        interpret=interpret,
    )(alpha.astype(jnp.float32), beta.astype(jnp.float32),
      w1.astype(jnp.float32), w2.astype(jnp.float32), w3.astype(jnp.float32))
    return out[:n]


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _gen3(alpha, beta, w1, w2, w3, freq, normalize, block_n, interpret):
    return _generator3_pallas_raw(alpha, beta, w1, w2, w3, freq=freq,
                                  normalize=normalize, block_n=block_n,
                                  interpret=interpret)


def _gen3_fwd(alpha, beta, w1, w2, w3, freq, normalize, block_n, interpret):
    out = _generator3_pallas_raw(alpha, beta, w1, w2, w3, freq=freq,
                                 normalize=normalize, block_n=block_n,
                                 interpret=interpret)
    return out, (alpha, beta, w1, w2, w3)


def _gen3_bwd(freq, normalize, block_n, interpret, res, g):
    # Backward pass through the mathematically identical jnp reference
    # (interpret-mode pallas_call has no reverse-mode rule). Gradients w.r.t.
    # the frozen generator weights are dead code and DCE'd by XLA.
    from .ref import generator3_ref

    alpha, beta, w1, w2, w3 = res
    _, vjp = jax.vjp(
        lambda a, b, x, y, z: generator3_ref(a, b, x, y, z, freq, normalize),
        alpha, beta, w1, w2, w3)
    return vjp(g)


_gen3.defvjp(_gen3_fwd, _gen3_bwd)


def generator3_pallas(alpha, beta, w1, w2, w3, *, freq: float,
                      normalize: bool = True, block_n: int = 64,
                      interpret: bool = True):
    """Differentiable fused generator: Pallas forward, reference VJP."""
    return _gen3(alpha.astype(jnp.float32), beta.astype(jnp.float32),
                 w1, w2, w3, float(freq), bool(normalize), int(block_n),
                 bool(interpret))


def vmem_bytes(k: int, h: int, d: int, block_n: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint of one grid step of the fused kernel.

    Used by DESIGN.md/EXPERIMENTS.md to pick ``block_n`` against the ~16 MiB
    VMEM budget of a TPU core (operands + both hidden activations + output).
    """
    operands = block_n * k + block_n + k * h + h * h + h * d
    activations = 2 * block_n * h + block_n * d
    return (operands + activations) * dtype_bytes
