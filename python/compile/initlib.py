"""Synthesize initial tensor values from manifest init laws + a scalar seed.

Python twin of ``rust/src/train/init.rs``. Both implementations must agree
bit-for-bit (golden-tested): the Rust coordinator uses this to build the
PJRT inputs at runtime, the Python tests use it to sanity-train lowered
graphs and to pin the Rust results.

An *init law* is the ``init`` dict of a manifest input spec, interpreted in
the context of the executable's leaf registry (``meta.registry``).
"""

from __future__ import annotations

import math

import numpy as np

from . import rng
from .genutil import GenCfg, make_weights


def _draw(dist: str, param: float, n: int, stream: int) -> np.ndarray:
    if dist == "zeros":
        return np.zeros(n, np.float32)
    if dist == "ones":
        return np.ones(n, np.float32)
    if dist == "sym_uniform":
        return rng.symmetric_f32(stream, n, param)
    if dist == "normal":
        return rng.normal_f32(stream, n, param)
    raise ValueError(f"unknown dist {dist!r}")


def _leaves(registry: dict, compress: bool):
    return [l for l in registry["leaves"] if l["compress"] == compress]


def _leaf_size(l: dict) -> int:
    n = 1
    for s in l["shape"]:
        n *= s
    return n


def _lora_targets(registry: dict):
    return [l for l in registry["leaves"] if l["compress"] and l["lora"]]


def init_tensor(init: dict, shape, registry: dict, seed: int) -> np.ndarray:
    """Build one input tensor according to its init law."""
    n = int(np.prod(shape)) if shape else 1
    kind = init["kind"]
    if kind == "zeros":
        return np.zeros(shape, np.float32)
    if kind == "ones":
        return np.ones(shape, np.float32)
    if kind == "sym_uniform":
        s = rng.substream(seed, init.get("tag", rng.TAG_COEF))
        return _draw("sym_uniform", init["bound"], n, s).reshape(shape)
    if kind == "comp_leaves":
        parts = [
            _draw(l["dist"], l["param"], _leaf_size(l),
                  rng.substream(seed, rng.TAG_THETA0 + i))
            for i, l in enumerate(_leaves(registry, True))
        ]
        return np.concatenate(parts) if parts else np.zeros(0, np.float32)
    if kind == "raw_leaves":
        parts = [
            _draw(l["dist"], l["param"], _leaf_size(l),
                  rng.substream(seed, rng.TAG_RAW + i))
            for i, l in enumerate(_leaves(registry, False))
        ]
        out = np.concatenate(parts) if parts else np.zeros(0, np.float32)
        if out.size == 0:  # methods pad empty raw to size 1
            out = np.zeros(1, np.float32)
        return out
    if kind == "gen_layer":
        cfg = GenCfg(**init["gen"])
        return make_weights(cfg, seed)[init["layer"]]
    if kind == "lora_a":
        r = init["rank"]
        parts = [
            _draw("sym_uniform", 1.0 / math.sqrt(l["lora"][0]), l["lora"][0] * r,
                  rng.substream(seed, rng.TAG_LORA + j))
            for j, l in enumerate(_lora_targets(registry))
        ]
        return np.concatenate(parts)
    if kind == "lora0":
        r = init["rank"]
        a = init_tensor({"kind": "lora_a", "rank": r}, None, registry, seed)
        db = sum(r * l["lora"][1] for l in _lora_targets(registry))
        return np.concatenate([a, np.zeros(db, np.float32)])
    if kind == "nola_basis":
        m, r, side = init["m"], init["rank"], init["side"]
        parts = []
        for j, l in enumerate(_lora_targets(registry)):
            a, b = l["lora"]
            if side == "a":
                s = rng.substream(seed, rng.TAG_NOLA_BASIS + 2 * j)
                parts.append(_draw("sym_uniform", 1.0 / math.sqrt(a), m * a * r, s))
            else:
                s = rng.substream(seed, rng.TAG_NOLA_BASIS + 2 * j + 1)
                parts.append(_draw("sym_uniform", 1.0 / math.sqrt(r), m * r * b, s))
        return np.concatenate(parts)
    if kind == "nola_coef":
        m = init["m"]
        s = rng.substream(seed, rng.TAG_COEF)
        return _draw("sym_uniform", 1.0 / math.sqrt(m), n, s).reshape(shape)
    raise ValueError(f"unknown init kind {kind!r}")


def init_all(inputs_meta: list[dict], registry: dict, seed: int) -> dict:
    """Initial values for every spec that has an init law."""
    out = {}
    for spec in inputs_meta:
        if spec.get("init"):
            out[spec["name"]] = init_tensor(spec["init"], tuple(spec["shape"]),
                                            registry, seed)
    return out
