"""AOT driver: lower every catalog executable to HLO text + manifest.

HLO *text* (not ``.serialize()``) is the interchange format — jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts [--only core,lm] [--force]
    python -m compile.aot --list
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .methods import Built

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_one(built: Built, out_dir: str) -> dict:
    arg_specs = [jax.ShapeDtypeStruct(tuple(s.shape), _DTYPES[s.dtype])
                 for s in built.inputs]
    # keep_unused: the positional manifest contract requires every declared
    # input to stay a parameter even if the graph ignores it (e.g. `raw` in
    # reconstruct graphs, gw's in linear-variant evals).
    lowered = jax.jit(built.fn, keep_unused=True).lower(*arg_specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{built.name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)

    out_shapes = jax.eval_shape(built.fn, *arg_specs)
    if len(out_shapes) != len(built.outputs):
        raise RuntimeError(
            f"{built.name}: declared {len(built.outputs)} outputs, "
            f"graph produces {len(out_shapes)}")
    outputs = []
    for (name, _shape, _dt), s in zip(built.outputs, out_shapes):
        dt = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[s.dtype]
        outputs.append({"name": name, "shape": list(s.shape), "dtype": dt})

    return {
        "name": built.name,
        "file": f"{built.name}.hlo.txt",
        "inputs": [s.to_meta() for s in built.inputs],
        "outputs": outputs,
        "meta": built.meta,
        "hlo_bytes": len(text),
    }


def _source_stamp() -> str:
    """Hash of the compile-path sources — artifacts rebuilt when it changes."""
    h = hashlib.sha256()
    root = os.path.dirname(__file__)
    for dirpath, _, files in sorted(os.walk(root)):
        if "__pycache__" in dirpath:
            continue
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(dirpath, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default="", help="comma-separated groups")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    from .specs import all_specs

    catalog = all_specs()
    if args.list:
        for g, b in catalog:
            print(f"{g:12s} {b.name}")
        return 0

    only = set(args.only.split(",")) if args.only else None
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    man_path = os.path.join(out_dir, "manifest.json")
    manifest = {"version": 1, "entries": {}}
    if os.path.exists(man_path):
        with open(man_path) as f:
            manifest = json.load(f)
    stamp = _source_stamp()
    stale = manifest.get("stamp") != stamp

    n_built = n_skipped = 0
    t_all = time.time()
    for group, built in catalog:
        if only and group not in only:
            continue
        path = os.path.join(out_dir, f"{built.name}.hlo.txt")
        have = built.name in manifest["entries"] and os.path.exists(path)
        if have and not args.force and not stale:
            n_skipped += 1
            continue
        t0 = time.time()
        entry = lower_one(built, out_dir)
        entry["group"] = group
        manifest["entries"][built.name] = entry
        n_built += 1
        print(f"[aot] {group:12s} {built.name:32s} "
              f"{entry['hlo_bytes']/1024:8.0f} KiB  {time.time()-t0:5.1f}s",
              flush=True)

    if not only:
        # Partial (--only) builds must not mark the whole catalog fresh:
        # other groups were lowered from older sources.
        manifest["stamp"] = stamp
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] built {n_built}, skipped {n_skipped} (up to date), "
          f"total {time.time()-t_all:.1f}s → {man_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
