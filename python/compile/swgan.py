"""SWGAN-style generator training (paper §3.1, Fig 2 right panel, Table 9).

The generator φ is optimized to push U([-L,L]^k) onto U(S^{d-1}) by
minimizing the *sliced* Wasserstein-2 distance between φ(α) batches and
uniform sphere samples: project both point clouds onto P random directions,
sort each 1-D projection, and penalize the pairwise squared differences.
The Rust coordinator drives the loop — it supplies fresh α / target /
projection tensors each step (from the shared SplitMix64 streams) and feeds
the updated weights back in, so the artifact is a single Adam step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import genutil
from .genutil import GenCfg
from .methods import Built, TensorSpec, _adam_update


@jax.custom_vjp
def _sorted_sq_diff(xp, tp):
    """mean((sort(xp) − sort(tp))²) for 1-D xp, tp — the W2² between their
    empirical distributions. Hand-written VJP: the optimal assignment is
    locally constant in the inputs, so the gradient is the pairwise residual
    scattered back through the argsort permutations (this sidesteps
    jax's sort-VJP, which lowers to a gather the pinned jaxlib rejects).
    """
    dx = jnp.sort(xp) - jnp.sort(tp)
    return jnp.mean(dx * dx)


def _ssd_fwd(xp, tp):
    ix = jnp.argsort(xp)
    it = jnp.argsort(tp)
    dx = jnp.take(xp, ix) - jnp.take(tp, it)
    return jnp.mean(dx * dx), (ix, it, dx)


def _ssd_bwd(res, g):
    ix, it, dx = res
    b = dx.shape[0]
    gx = jnp.zeros_like(dx).at[ix].set(2.0 * dx / b * g)
    gt = jnp.zeros_like(dx).at[it].set(-2.0 * dx / b * g)
    return gx, gt


_sorted_sq_diff.defvjp(_ssd_fwd, _ssd_bwd)


def sw2_distance(xs, ts, proj):
    """Sliced W2² between point clouds xs, ts: [B, d] under proj [d, P]."""
    xp = xs @ proj
    tp = ts @ proj
    total = jnp.float32(0.0)
    for j in range(proj.shape[1]):
        total = total + _sorted_sq_diff(xp[:, j], tp[:, j])
    return total / proj.shape[1]


def build_swgan_step(name: str, cfg: GenCfg, batch: int, n_proj: int) -> Built:
    shapes = cfg.layer_shapes()
    depth = len(shapes)

    gws = [TensorSpec(f"gw{i}", s, role="trainable",
                      init={"kind": "gen_layer", "layer": i, "gen": cfg.to_meta()})
           for i, s in enumerate(shapes)]
    opt_m = [TensorSpec(f"m_gw{i}", s, role="opt") for i, s in enumerate(shapes)]
    opt_v = [TensorSpec(f"v_gw{i}", s, role="opt") for i, s in enumerate(shapes)]
    hyper = [TensorSpec("t", (), "f32", "hyper"), TensorSpec("lr", (), "f32", "hyper")]
    data = [
        TensorSpec("alpha", (batch, cfg.k), role="data"),
        TensorSpec("target", (batch, cfg.d), role="data"),
        TensorSpec("proj", (cfg.d, n_proj), role="data"),
    ]

    def step(*args):
        ws = list(args[:depth])
        ms = list(args[depth: 2 * depth])
        vs = list(args[2 * depth: 3 * depth])
        t, lr, alpha, target, proj = args[3 * depth:]

        def loss_fn(ws_tuple):
            out = genutil.generator_ref(cfg, list(ws_tuple), alpha,
                                        jnp.ones((batch,), jnp.float32))
            return sw2_distance(out, target, proj)

        loss, grads = jax.value_and_grad(loss_fn)(tuple(ws))
        t1 = t + 1.0
        ws1, ms1, vs1 = [], [], []
        for p, g, m, v in zip(ws, grads, ms, vs):
            p1, m1, v1 = _adam_update(p, g, m, v, t1, lr)
            ws1.append(p1)
            ms1.append(m1)
            vs1.append(v1)
        return (*ws1, *ms1, *vs1, t1, loss)

    inputs = gws + opt_m + opt_v + hyper + data
    outputs = (
        [(f"gw{i}", s, "f32") for i, s in enumerate(shapes)]
        + [(f"m_gw{i}", s, "f32") for i, s in enumerate(shapes)]
        + [(f"v_gw{i}", s, "f32") for i, s in enumerate(shapes)]
        + [("t", (), "f32"), ("loss", (), "f32")]
    )
    meta = {"kind": "swgan_step", "gen": cfg.to_meta(), "batch": batch,
            "n_proj": n_proj, "registry": {"Dc": 0, "R": 0, "leaves": []}}
    return Built(name, step, inputs, outputs, meta)
