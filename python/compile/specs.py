"""The artifact catalog: every AOT-compiled executable in the system.

Each entry is a (group, Built) pair; ``aot.py`` lowers Built.fn to HLO text
and records the positional input/output specs + metadata in
``artifacts/manifest.json``. Groups let `make artifacts ONLY=core,lm`
rebuild a subset during development; benches load executables by name.

Scaling notes (DESIGN.md §7): model dims and generator widths are scaled so
a full table regenerates in CPU-minutes. Generator width tracks the chunk
size d (the paper's Table 15 shows width saturates early); the paper's
exact defaults (k=9, depth 3, freq 4.5, U[-1/n,1/n]) are kept.
"""

from __future__ import annotations

import math

from .genutil import GenCfg
from . import models
from .methods import (Built, Dense, Lora, Mcnc, McncLora, NolaLora, Registry,
                      TensorSpec, build_eval_step, build_predict,
                      build_reconstruct, build_train_step)
from .swgan import build_swgan_step


def gen_width(d: int) -> int:
    return int(min(256, max(32, d // 4)))


def gen_for_rate(Dc: int, rate: float, k: int = 9, **kw) -> GenCfg:
    d = max(int(math.ceil((k + 1) / rate)), k + 1)
    return GenCfg(k=k, d=d, width=kw.pop("width", gen_width(d)), **kw)


def gen_for_budget(Dc: int, budget: int, k: int = 9, **kw) -> GenCfg:
    """Chunk size so that n·(k+1) ≈ budget trainable params."""
    n = max(1, budget // (k + 1))
    d = int(math.ceil(Dc / n))
    return GenCfg(k=k, d=d, width=kw.pop("width", gen_width(d)), **kw)


def _family(out, group, name, model, method, batch, train=True, evals=True,
            predict=False, recon=False):
    if train:
        out.append((group, build_train_step(f"{name}_train", model, method, batch)))
    if evals:
        out.append((group, build_eval_step(f"{name}_eval", model, method, batch)))
    if predict:
        out.append((group, build_predict(f"{name}_predict", model, method, batch)))
    if recon:
        out.append((group, build_reconstruct(f"{name}_recon", model, method)))


def build_gen_fwd(name: str, cfg: GenCfg, n: int) -> Built:
    """Standalone generator forward (the L1 kernel as its own executable —
    the serving hot path reconstructs adapters through this)."""
    from .kernels.generator import generator3_pallas
    from . import genutil

    gws = [TensorSpec(f"gw{i}", s,
                      init={"kind": "gen_layer", "layer": i, "gen": cfg.to_meta()})
           for i, s in enumerate(cfg.layer_shapes())]
    inputs = [TensorSpec("alpha", (n, cfg.k), role="trainable",
                         init={"kind": "zeros"}),
              TensorSpec("beta", (n,), role="trainable",
                         init={"kind": "zeros"})] + gws

    def fwd(alpha, beta, *ws):
        if cfg.depth == 3 and cfg.act == "sine" and not cfg.residual:
            out = generator3_pallas(alpha, beta, *ws, freq=cfg.freq,
                                    normalize=cfg.normalize)
        else:
            out = genutil.generator_ref(cfg, list(ws), alpha, beta)
        return (out,)

    meta = {"kind": "gen_fwd", "gen": cfg.to_meta(), "n_chunks": n,
            "recon_flops": n * cfg.flops_per_chunk(),
            "registry": {"Dc": 0, "R": 0, "leaves": []}}
    return Built(name, fwd, inputs, [("out", (n, cfg.d), "f32")], meta)


def all_specs() -> list[tuple[str, Built]]:
    out: list[tuple[str, Built]] = []

    # ---------------- core: the paper's MNIST-ablation model ----------------
    mlp = models.MlpCfg(hidden=256)
    reg_mlp = Registry(mlp.leaves())
    B = 128
    gen02 = GenCfg(k=9, d=5000, width=256)  # paper default, width scaled
    _family(out, "core", "mlp_dense", mlp, Dense(reg_mlp), B, recon=True)
    _family(out, "core", "mlp_mcnc02", mlp, Mcnc(reg_mlp, gen02), B, recon=True)
    n02 = int(math.ceil(reg_mlp.Dc / gen02.d))
    out.append(("core", build_gen_fwd("gen_mlp02_fwd", gen02, n02)))

    # ---------------- ablations (Tables 5, 6, 7, 13, 15, 16) ----------------
    for act in ["sigmoid", "relu", "lrelu", "elu", "linear"]:
        m = Mcnc(reg_mlp, GenCfg(k=9, d=5000, width=256, act=act),
                 name=f"mcnc_{act}")
        _family(out, "abl_act", f"mlp_mcnc02_{act}", mlp, m, B)
    _family(out, "abl_freq", "mlp_mcnc02_freqin", mlp,
            Mcnc(reg_mlp, gen02, freq_input=True), B)
    # Table 7: model size sweep at fixed 54 chunks (540 trainable params).
    for hidden in [16, 32, 64, 128, 512]:
        m2 = models.MlpCfg(hidden=hidden)
        r2 = Registry(m2.leaves())
        d = int(math.ceil(r2.Dc / n02))
        _family(out, "abl_scale", f"mlp{hidden}_mcnc_fix", m2,
                Mcnc(r2, GenCfg(k=9, d=d, width=gen_width(d))), B)
    # Table 13: k/d at fixed rate.
    for k, d in [(1, 1000), (3, 2000), (7, 4000), (15, 8000), (31, 16000)]:
        _family(out, "abl_kd", f"mlp_mcnc_k{k}", mlp,
                Mcnc(reg_mlp, GenCfg(k=k, d=d, width=gen_width(d))), B)
    # Table 15: generator width.
    for w in [64, 128, 512, 1024]:
        _family(out, "abl_width", f"mlp_mcnc02_w{w}", mlp,
                Mcnc(reg_mlp, GenCfg(k=9, d=5000, width=w)), B)
    # Table 16: generator depth (± residual).
    for depth in [2, 4, 5]:
        _family(out, "abl_depth", f"mlp_mcnc02_dep{depth}", mlp,
                Mcnc(reg_mlp, GenCfg(k=9, d=5000, width=256, depth=depth)), B)
    for depth in [3, 4, 5]:
        _family(out, "abl_depth", f"mlp_mcnc02_dep{depth}res", mlp,
                Mcnc(reg_mlp, GenCfg(k=9, d=5000, width=256, depth=depth,
                                     residual=True)), B)

    # ---------------- Table 1: ViT vs pruning ----------------
    vit = models.ViTCfg()
    reg_vit = Registry(vit.leaves())
    BV = 64
    _family(out, "vit", "vit_dense", vit, Dense(reg_vit), BV, recon=True)
    for pct in [50, 20, 10, 5, 2, 1]:
        _family(out, "vit", f"vit_mcnc{pct}", vit,
                Mcnc(reg_vit, gen_for_rate(reg_vit.Dc, pct / 100.0)), BV)

    # ---------------- Tables 2 & 3: ResNets vs PRANC/NOLA ----------------
    r20c10 = models.ResNetCfg(blocks_per_stage=3, num_classes=10)
    reg20 = Registry(r20c10.leaves())
    BR = 32
    _family(out, "resnet", "r20c10_dense", r20c10, Dense(reg20), BR, recon=True)
    for pct in [10, 5, 2, 1]:
        _family(out, "resnet", f"r20c10_mcnc{pct}", r20c10,
                Mcnc(reg20, gen_for_rate(reg20.Dc, pct / 100.0)), BR)
    for pct in [2, 1]:
        g = gen_for_rate(reg20.Dc, pct / 100.0, act="linear", normalize=False)
        _family(out, "resnet", f"r20c10_pranc{pct}", r20c10,
                Mcnc(reg20, g, name="pranc"), BR)
        # MCNC over LoRA(8) factors at the same trainable budget.
        regl = reg20
        rank = 8
        _, Da, Db = regl.lora_dims(rank)
        budget = Mcnc(reg20, gen_for_rate(reg20.Dc, pct / 100.0)).meta()["trainable_comp"]
        gl = gen_for_budget(Da + Db, budget, k=9)
        _family(out, "resnet", f"r20c10_mcnclora{pct}", r20c10,
                McncLora(reg20, rank, gl), BR)
    # NOLA at the 1% budget.
    budget1 = Mcnc(reg20, gen_for_rate(reg20.Dc, 0.01)).meta()["trainable_comp"]
    L20 = len(reg20.lora_targets)
    m20 = max(2, budget1 // (2 * L20))
    _family(out, "resnet", "r20c10_nola", r20c10, NolaLora(reg20, 8, m20), BR)

    # Table 3 settings: ≈5k trainable params on all four (arch, dataset).
    t3 = [
        ("r20c10", models.ResNetCfg(3, num_classes=10)),
        ("r20c100", models.ResNetCfg(3, num_classes=100)),
        ("r56c10", models.ResNetCfg(9, num_classes=10)),
        ("r56c100", models.ResNetCfg(9, num_classes=100)),
    ]
    for nm, cfg in t3:
        reg = Registry(cfg.leaves())
        _family(out, "resnet_t3", f"{nm}_dense5k", cfg, Dense(reg), BR)
        g = gen_for_budget(reg.Dc, 5000)
        _family(out, "resnet_t3", f"{nm}_mcnc5k", cfg, Mcnc(reg, g), BR)
        gp = gen_for_budget(reg.Dc, 5000, act="linear", normalize=False)
        _family(out, "resnet_t3", f"{nm}_pranc5k", cfg, Mcnc(reg, gp, name="pranc"), BR)
        L = len(reg.lora_targets)
        m = max(2, 5000 // (2 * L))
        _family(out, "resnet_t3", f"{nm}_nola5k", cfg, NolaLora(reg, 8, m), BR)
        rank = 8
        _, Da, Db = reg.lora_dims(rank)
        gl = gen_for_budget(Da + Db, 5000)
        _family(out, "resnet_t3", f"{nm}_mcnclora5k", cfg, McncLora(reg, rank, gl), BR)

    # ---------------- Table 4: LM PEFT + serving ----------------
    lm = models.LmCfg(vocab=128, dim=96, depth=2, heads=4, seq=32)
    reg_lm = Registry(lm.leaves())
    BL = 16
    _family(out, "lm", "lm_dense", lm, Dense(reg_lm), BL, predict=True)
    rank = 8
    _family(out, "lm", "lm_lora1", lm, Lora(reg_lm, 1), BL, predict=True, recon=True)
    _family(out, "lm", "lm_lora8", lm, Lora(reg_lm, rank), BL, predict=True, recon=True)
    gen_ad = GenCfg(k=5, d=512, width=64)
    mcl = McncLora(reg_lm, rank, gen_ad)
    _family(out, "lm", "lm_mcnclora8", lm, mcl, BL, predict=True, recon=True)
    Llm = len(reg_lm.lora_targets)
    m_lm = max(2, mcl.meta()["trainable_comp"] // (2 * Llm))
    _family(out, "lm", "lm_nola8", lm, NolaLora(reg_lm, rank, m_lm), BL,
            predict=True, recon=True)
    # Standalone adapter-reconstruction kernel for the serving hot path.
    out.append(("lm", build_gen_fwd("gen_adapter_fwd", gen_ad, mcl.n)))

    # ---------------- Fig 2 / Table 9: generator training ----------------
    out.append(("sphere", build_swgan_step(
        "swgan_k1d3", GenCfg(k=1, d=3, width=256, depth=3, normalize=True),
        batch=512, n_proj=32)))
    g_t3 = gen_for_budget(reg20.Dc, 5000, normalize=True)
    out.append(("sphere", build_swgan_step(
        "swgan_r20gen", g_t3, batch=128, n_proj=32)))
    reg20c100 = Registry(models.ResNetCfg(3, num_classes=100).leaves())
    g_t3c100 = gen_for_budget(reg20c100.Dc, 5000, normalize=True)
    out.append(("sphere", build_swgan_step(
        "swgan_r20c100gen", g_t3c100, batch=128, n_proj=32)))

    return out


def spec_names() -> list[str]:
    return [b.name for _, b in all_specs()]
