"""SplitMix64-based deterministic parameter streams.

This module is the *Python twin* of ``rust/src/util/prng.rs``. Both sides
must produce bit-identical f32 streams from the same seed: the Rust
coordinator owns all seeds at runtime (generator weights, θ0, NOLA bases are
PJRT *inputs*, never baked into HLO), while the Python tests re-derive the
same tensors to pin kernel/model numerics.

Stream construction
-------------------
``splitmix64`` is a counter-based mix: output ``i`` of stream ``s`` is
``mix(s + (i+1)*GAMMA)`` — embarrassingly vectorizable on both sides.
Sub-streams (per layer / per leaf) are derived as ``mix(seed ^ (tag * TAG)``,
so each tensor can be generated independently and in any order.

f32 uniforms use the top 24 bits (``(x >> 40) * 2^-24``) so the f32 math is
exact and byte-for-byte reproducible across numpy and Rust.
"""

from __future__ import annotations

import numpy as np

GAMMA = np.uint64(0x9E3779B97F4A7C15)
TAG = np.uint64(0xBF58476D1CE4E5B9)
_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)
_U64 = np.uint64


def mix(z: np.ndarray | int) -> np.ndarray:
    """The splitmix64 finalizer. Accepts scalars or uint64 arrays."""
    z = np.asarray(z, dtype=np.uint64)
    with np.errstate(over="ignore"):
        z = (z ^ (z >> _U64(30))) * _M1
        z = (z ^ (z >> _U64(27))) * _M2
        return z ^ (z >> _U64(31))


def substream(seed: int, tag: int) -> int:
    """Derive an independent stream seed for (seed, tag)."""
    with np.errstate(over="ignore"):
        return int(mix(_U64(seed) ^ (_U64(tag) * TAG)))


def raw_u64(seed: int, n: int) -> np.ndarray:
    """First ``n`` raw u64 outputs of stream ``seed``."""
    idx = np.arange(1, n + 1, dtype=np.uint64)
    with np.errstate(over="ignore"):
        return mix(_U64(seed) + idx * GAMMA)


def uniform_f32(seed: int, n: int, lo: float = 0.0, hi: float = 1.0) -> np.ndarray:
    """``n`` f32 uniforms in [lo, hi) — bit-identical to Rust."""
    u = (raw_u64(seed, n) >> _U64(40)).astype(np.float32) * np.float32(2.0**-24)
    return (u * (np.float32(hi) - np.float32(lo)) + np.float32(lo)).astype(np.float32)


def symmetric_f32(seed: int, n: int, bound: float) -> np.ndarray:
    """``n`` f32 uniforms in [-bound, bound) — the generator-weight law."""
    u = (raw_u64(seed, n) >> _U64(40)).astype(np.float32) * np.float32(2.0**-24)
    return ((np.float32(2.0) * u - np.float32(1.0)) * np.float32(bound)).astype(np.float32)


def normal_f32(seed: int, n: int, std: float = 1.0) -> np.ndarray:
    """Box–Muller normals. Matches Rust to ~1e-5 (libm sin/cos may differ in ulp)."""
    m = (n + 1) // 2
    u = raw_u64(seed, 2 * m)
    u1 = ((u[:m] >> _U64(40)).astype(np.float64) + 1.0) * 2.0**-24  # (0, 1]
    u2 = (u[m:] >> _U64(40)).astype(np.float64) * 2.0**-24  # [0, 1)
    r = np.sqrt(-2.0 * np.log(u1))
    out = np.empty(2 * m, dtype=np.float32)
    out[0::2] = (r * np.cos(2.0 * np.pi * u2)).astype(np.float32)
    out[1::2] = (r * np.sin(2.0 * np.pi * u2)).astype(np.float32)
    return (out[:n] * np.float32(std)).astype(np.float32)


# Well-known stream tags shared with rust/src/util/prng.rs. Keep in sync.
TAG_GEN_LAYER = 0x47454E00  # + layer index
TAG_THETA0 = 0x54480000  # + compressed-leaf index
TAG_RAW = 0x52415700  # + raw-leaf index
TAG_LORA = 0x4C4F5200  # + lora-target index (A factors)
TAG_NOLA_BASIS = 0x4E4F4C00  # + 2*target (A) / 2*target+1 (B)
TAG_COEF = 0x434F4500
TAG_DATA = 0x44415400
TAG_SPHERE = 0x53504800
TAG_ALPHA = 0x414C5000
TAG_PROJ = 0x50524A00
