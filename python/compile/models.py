"""L2 — the model zoo, as pure-functional jax.

Every model is described by a flat *leaf registry*: an ordered list of named
parameter tensors, each marked ``compress`` (participates in the
reparameterization and in compression-rate accounting) or raw (trained
dense and excluded from the rate, exactly as the paper excludes norm /
position-embedding / CLS parameters). ``apply`` consumes a ``{name: array}``
dict; the methods layer (``methods.py``) is responsible for materializing
that dict from a compressed trainable state.

Initialization laws are recorded per leaf (dist + parameter) so the Rust
coordinator can synthesize θ0 / raw inits from a seed via the shared
SplitMix64 streams — initial values are PJRT *inputs*, never HLO constants.

Models (scaled-down but topology-faithful stand-ins, DESIGN.md §7):
  mlp        784→h→h→10            — the paper's MNIST ablation model
  resnet     CIFAR-style ResNet-20/56 (GroupNorm for BatchNorm)
  vit        patch-4 ViT-tiny for 32×32
  lm         decoder-only transformer LM (the LLaMA-2 PEFT analog)
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax import lax


# --------------------------------------------------------------------------
# Leaf registry
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Leaf:
    name: str
    shape: tuple
    compress: bool
    dist: str  # sym_uniform | normal | zeros | ones
    param: float = 0.0  # bound (sym_uniform) or std (normal)
    lora: tuple | None = None  # (a, b): matrix view for LoRA targeting

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def to_meta(self) -> dict:
        return {
            "name": self.name,
            "shape": list(self.shape),
            "compress": self.compress,
            "dist": self.dist,
            "param": self.param,
            "lora": list(self.lora) if self.lora else None,
        }


def _w(name, shape, fan_in, lora=None, compress=True):
    """Weight leaf with torch-style U[-1/sqrt(fan_in), 1/sqrt(fan_in)) init."""
    return Leaf(name, tuple(shape), compress, "sym_uniform", 1.0 / math.sqrt(fan_in), lora)


def _zeros(name, shape, compress=False):
    return Leaf(name, tuple(shape), compress, "zeros")


def _ones(name, shape):
    return Leaf(name, tuple(shape), False, "ones")


def _emb(name, shape, std=0.02):
    return Leaf(name, tuple(shape), False, "normal", std)


# --------------------------------------------------------------------------
# Shared nn ops
# --------------------------------------------------------------------------

def layer_norm(x, scale, bias, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def group_norm(x, scale, bias, groups=8, eps=1e-5):
    """GroupNorm over NHWC (our BatchNorm stand-in — see DESIGN.md §7)."""
    b, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(b, h, w, g, c // g)
    mu = xg.mean((1, 2, 4), keepdims=True)
    var = ((xg - mu) ** 2).mean((1, 2, 4), keepdims=True)
    xg = (xg - mu) / jnp.sqrt(var + eps)
    return xg.reshape(b, h, w, c) * scale + bias


def conv2d(x, w, stride=1):
    """NHWC x HWIO → NHWC, SAME padding."""
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def attention(x, wqkv, bqkv, wproj, bproj, heads, causal=False):
    b, t, dm = x.shape
    dh = dm // heads
    qkv = x @ wqkv + bqkv  # [b, t, 3*dm]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads_first(z):
        return z.reshape(b, t, heads, dh).transpose(0, 2, 1, 3)

    q, k, v = heads_first(q), heads_first(k), heads_first(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(dh)  # [b, h, t, t]
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        att = jnp.where(mask, att, jnp.float32(-1e9))
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, dm)
    return out @ wproj + bproj


def softmax_xent(logits, y, num_classes):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return jnp.mean(nll), acc


# --------------------------------------------------------------------------
# MLP (MNIST-shape ablation model)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MlpCfg:
    in_dim: int = 784
    hidden: int = 256
    out_dim: int = 10

    name: str = "mlp"

    def leaves(self):
        c = self
        return [
            _w("w1", (c.in_dim, c.hidden), c.in_dim, lora=(c.in_dim, c.hidden)),
            _zeros("b1", (c.hidden,)),
            _w("w2", (c.hidden, c.hidden), c.hidden, lora=(c.hidden, c.hidden)),
            _zeros("b2", (c.hidden,)),
            _w("w3", (c.hidden, c.out_dim), c.hidden, lora=(c.hidden, c.out_dim)),
            _zeros("b3", (c.out_dim,)),
        ]

    def apply(self, p, x):
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ p["w1"] + p["b1"])
        x = jax.nn.relu(x @ p["w2"] + p["b2"])
        return x @ p["w3"] + p["b3"]

    def loss_and_acc(self, p, x, y):
        return softmax_xent(self.apply(p, x), y, self.out_dim)

    def data_shapes(self, batch):
        return (batch, self.in_dim), (batch,)


# --------------------------------------------------------------------------
# CIFAR-style ResNet (GroupNorm)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ResNetCfg:
    blocks_per_stage: int = 3  # 3 → ResNet-20, 9 → ResNet-56
    widths: tuple = (16, 32, 64)
    num_classes: int = 10
    image: int = 32
    channels: int = 3

    @property
    def name(self):
        depth = 6 * self.blocks_per_stage + 2
        return f"resnet{depth}c{self.num_classes}"

    def _block_names(self):
        cin = self.widths[0]
        out = []
        for s, cout in enumerate(self.widths):
            for b in range(self.blocks_per_stage):
                stride = 2 if (s > 0 and b == 0) else 1
                out.append((f"s{s}b{b}", cin, cout, stride))
                cin = cout
        return out

    def leaves(self):
        c = self
        ls = [
            _w("conv0", (3, 3, c.channels, c.widths[0]), 9 * c.channels,
               lora=(3 * c.channels, 3 * c.widths[0])),
            _ones("gn0s", (c.widths[0],)), _zeros("gn0b", (c.widths[0],)),
        ]
        for nm, cin, cout, stride in self._block_names():
            ls += [
                _w(f"{nm}.conv1", (3, 3, cin, cout), 9 * cin, lora=(3 * cin, 3 * cout)),
                _ones(f"{nm}.gn1s", (cout,)), _zeros(f"{nm}.gn1b", (cout,)),
                _w(f"{nm}.conv2", (3, 3, cout, cout), 9 * cout, lora=(3 * cout, 3 * cout)),
                _ones(f"{nm}.gn2s", (cout,)), _zeros(f"{nm}.gn2b", (cout,)),
            ]
            if cin != cout or stride != 1:
                ls.append(_w(f"{nm}.proj", (1, 1, cin, cout), cin, lora=(cin, cout)))
        ls += [
            _w("head.w", (c.widths[-1], c.num_classes), c.widths[-1],
               lora=(c.widths[-1], c.num_classes)),
            _zeros("head.b", (c.num_classes,)),
        ]
        return ls

    def apply(self, p, x):
        c = self
        x = x.reshape(x.shape[0], c.image, c.image, c.channels)
        h = jax.nn.relu(group_norm(conv2d(x, p["conv0"]), p["gn0s"], p["gn0b"]))
        for nm, cin, cout, stride in self._block_names():
            y = jax.nn.relu(group_norm(conv2d(h, p[f"{nm}.conv1"], stride),
                                       p[f"{nm}.gn1s"], p[f"{nm}.gn1b"]))
            y = group_norm(conv2d(y, p[f"{nm}.conv2"]), p[f"{nm}.gn2s"], p[f"{nm}.gn2b"])
            sc = conv2d(h, p[f"{nm}.proj"], stride) if f"{nm}.proj" in p else h
            h = jax.nn.relu(y + sc)
        h = h.mean((1, 2))
        return h @ p["head.w"] + p["head.b"]

    def loss_and_acc(self, p, x, y):
        return softmax_xent(self.apply(p, x), y, self.num_classes)

    def data_shapes(self, batch):
        return (batch, self.image * self.image * self.channels), (batch,)


# --------------------------------------------------------------------------
# ViT-tiny
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ViTCfg:
    image: int = 32
    patch: int = 4
    dim: int = 64
    depth: int = 4
    heads: int = 4
    mlp_ratio: int = 2
    num_classes: int = 10
    channels: int = 3

    @property
    def name(self):
        return f"vit{self.dim}d{self.depth}c{self.num_classes}"

    @property
    def n_tokens(self):
        return (self.image // self.patch) ** 2 + 1

    @property
    def patch_dim(self):
        return self.patch * self.patch * self.channels

    def leaves(self):
        c, d = self, self.dim
        ls = [
            _w("patch.w", (c.patch_dim, d), c.patch_dim, lora=(c.patch_dim, d)),
            _zeros("patch.b", (d,)),
            # pos/cls excluded from compression, like the paper.
            _emb("pos", (c.n_tokens, d)),
            _emb("cls", (1, d)),
        ]
        hid = d * c.mlp_ratio
        for i in range(c.depth):
            ls += [
                _ones(f"blk{i}.ln1s", (d,)), _zeros(f"blk{i}.ln1b", (d,)),
                _w(f"blk{i}.wqkv", (d, 3 * d), d, lora=(d, 3 * d)),
                _zeros(f"blk{i}.bqkv", (3 * d,)),
                _w(f"blk{i}.wproj", (d, d), d, lora=(d, d)),
                _zeros(f"blk{i}.bproj", (d,)),
                _ones(f"blk{i}.ln2s", (d,)), _zeros(f"blk{i}.ln2b", (d,)),
                _w(f"blk{i}.wfc1", (d, hid), d, lora=(d, hid)),
                _zeros(f"blk{i}.bfc1", (hid,)),
                _w(f"blk{i}.wfc2", (hid, d), hid, lora=(hid, d)),
                _zeros(f"blk{i}.bfc2", (d,)),
            ]
        ls += [
            _ones("lnf.s", (d,)), _zeros("lnf.b", (d,)),
            _w("head.w", (d, c.num_classes), d, lora=(d, c.num_classes)),
            _zeros("head.b", (c.num_classes,)),
        ]
        return ls

    def apply(self, p, x):
        c = self
        b = x.shape[0]
        g = c.image // c.patch
        x = x.reshape(b, g, c.patch, g, c.patch, c.channels)
        x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, g * g, c.patch_dim)
        h = x @ p["patch.w"] + p["patch.b"]
        cls = jnp.broadcast_to(p["cls"], (b, 1, c.dim))
        h = jnp.concatenate([cls, h], axis=1) + p["pos"]
        for i in range(c.depth):
            z = layer_norm(h, p[f"blk{i}.ln1s"], p[f"blk{i}.ln1b"])
            h = h + attention(z, p[f"blk{i}.wqkv"], p[f"blk{i}.bqkv"],
                              p[f"blk{i}.wproj"], p[f"blk{i}.bproj"], c.heads)
            z = layer_norm(h, p[f"blk{i}.ln2s"], p[f"blk{i}.ln2b"])
            z = jax.nn.gelu(z @ p[f"blk{i}.wfc1"] + p[f"blk{i}.bfc1"])
            h = h + z @ p[f"blk{i}.wfc2"] + p[f"blk{i}.bfc2"]
        h = layer_norm(h[:, 0], p["lnf.s"], p["lnf.b"])
        return h @ p["head.w"] + p["head.b"]

    def loss_and_acc(self, p, x, y):
        return softmax_xent(self.apply(p, x), y, self.num_classes)

    def data_shapes(self, batch):
        return (batch, self.image * self.image * self.channels), (batch,)


# --------------------------------------------------------------------------
# Decoder-only LM (the LLaMA-2 PEFT analog)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class LmCfg:
    vocab: int = 256
    dim: int = 128
    depth: int = 2
    heads: int = 4
    seq: int = 64
    mlp_ratio: int = 2

    @property
    def name(self):
        return f"lm{self.dim}d{self.depth}"

    def leaves(self):
        c, d = self, self.dim
        ls = [
            _emb("wte", (c.vocab, d)),
            _emb("wpe", (c.seq, d)),
        ]
        hid = d * c.mlp_ratio
        for i in range(c.depth):
            ls += [
                _ones(f"blk{i}.ln1s", (d,)), _zeros(f"blk{i}.ln1b", (d,)),
                _w(f"blk{i}.wqkv", (d, 3 * d), d, lora=(d, 3 * d)),
                _zeros(f"blk{i}.bqkv", (3 * d,)),
                _w(f"blk{i}.wproj", (d, d), d, lora=(d, d)),
                _zeros(f"blk{i}.bproj", (d,)),
                _ones(f"blk{i}.ln2s", (d,)), _zeros(f"blk{i}.ln2b", (d,)),
                _w(f"blk{i}.wfc1", (d, hid), d, lora=(d, hid)),
                _zeros(f"blk{i}.bfc1", (hid,)),
                _w(f"blk{i}.wfc2", (hid, d), hid, lora=(hid, d)),
                _zeros(f"blk{i}.bfc2", (d,)),
            ]
        ls += [
            _ones("lnf.s", (d,)), _zeros("lnf.b", (d,)),
            _w("head.w", (d, c.vocab), d, lora=(d, c.vocab)),
        ]
        return ls

    def apply(self, p, x):
        """x: int32 [b, t] → logits [b, t, vocab]."""
        c = self
        b, t = x.shape
        h = jnp.take(p["wte"], x, axis=0) + p["wpe"][None, :t]
        for i in range(c.depth):
            z = layer_norm(h, p[f"blk{i}.ln1s"], p[f"blk{i}.ln1b"])
            h = h + attention(z, p[f"blk{i}.wqkv"], p[f"blk{i}.bqkv"],
                              p[f"blk{i}.wproj"], p[f"blk{i}.bproj"], c.heads,
                              causal=True)
            z = layer_norm(h, p[f"blk{i}.ln2s"], p[f"blk{i}.ln2b"])
            z = jax.nn.gelu(z @ p[f"blk{i}.wfc1"] + p[f"blk{i}.bfc1"])
            h = h + z @ p[f"blk{i}.wfc2"] + p[f"blk{i}.bfc2"]
        h = layer_norm(h, p["lnf.s"], p["lnf.b"])
        return h @ p["head.w"]

    def loss_and_acc(self, p, x, y):
        """Next-token prediction: y[b, t] are the shifted targets."""
        logits = self.apply(p, x)
        return softmax_xent(logits, y, self.vocab)

    def data_shapes(self, batch):
        return (batch, self.seq), (batch, self.seq)

    data_dtype = "i32"


MODELS = {
    "mlp": MlpCfg,
    "resnet": ResNetCfg,
    "vit": ViTCfg,
    "lm": LmCfg,
}
