"""Generator configuration and weight construction (build-time twin of
``rust/src/mcnc/generator.rs``).

The MCNC generator is a frozen random MLP ``φ : R^k → S^{d-1}``:

    u = act(freq · α W₁); u = act(u W₂); …; v = act(u W_depth)
    φ(α) = v / ‖v‖₂           (if cfg.normalize)

No biases anywhere — with α = 0 every pre-activation is 0, so sine/linear
generators give φ(0) ∝ 0 and the reparameterized residual starts at exactly
zero (the paper's zero-init guarantee).
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict

import jax.numpy as jnp
import numpy as np

from . import rng


@dataclass(frozen=True)
class GenCfg:
    """Architecture + init of the generator φ (paper Table 10 defaults)."""

    k: int = 9  # input (manifold) dimension
    d: int = 5000  # output dimension = chunk size
    width: int = 1000  # hidden width
    depth: int = 3  # number of linear layers (>= 2)
    freq: float = 4.5  # input frequency (first-layer sine multiplier)
    act: str = "sine"  # sine|sigmoid|relu|lrelu|elu|linear
    # L2-normalize output onto S^{d-1}. Default False, matching the paper's
    # released implementation (appendix A.1: `generator(alpha) * beta`, no
    # normalization): at the zero init φ(0) = 0, and the exact-normalization
    # gradient is 0/0 there. The normalized variant is used for the sphere-
    # coverage analysis (Fig 2 / SWGAN), where inputs are never zero.
    normalize: bool = False
    residual: bool = False  # residual connections on hidden layers
    init: str = "uniform"  # uniform|normal
    init_scale: float = 1.0  # the paper's `c` factor on the init variance

    def layer_shapes(self) -> list[tuple[int, int]]:
        if self.depth < 2:
            raise ValueError("generator depth must be >= 2")
        dims = [self.k] + [self.width] * (self.depth - 1) + [self.d]
        return [(dims[i], dims[i + 1]) for i in range(self.depth)]

    def n_weights(self) -> int:
        return sum(a * b for a, b in self.layer_shapes())

    def flops_per_chunk(self) -> int:
        """FLOPs to reconstruct one d-chunk: matmuls + activations + scale.

        Matches the paper's Appendix A.6 accounting: 2·Σ fan_in·fan_out for
        the matmuls plus d for the β scale (activation transcendentals are
        excluded there; we follow the same convention).
        """
        mm = 2 * sum(a * b for a, b in self.layer_shapes())
        return mm + self.d

    def to_meta(self) -> dict:
        return asdict(self)


def make_weights(cfg: GenCfg, seed: int) -> list[np.ndarray]:
    """Frozen generator weights from a scalar seed (layer i uses substream
    ``seed ^ (TAG_GEN_LAYER + i)``); U[-c/fan_in, c/fan_in) by default."""
    ws = []
    for i, (fan_in, fan_out) in enumerate(cfg.layer_shapes()):
        s = rng.substream(seed, rng.TAG_GEN_LAYER + i)
        n = fan_in * fan_out
        if cfg.init == "uniform":
            bound = cfg.init_scale / fan_in
            w = rng.symmetric_f32(s, n, bound)
        elif cfg.init == "normal":
            # variance matched to the uniform baseline: Var(U[-1/n,1/n]) = 1/(3n^2)
            std = cfg.init_scale / (np.sqrt(3.0) * fan_in)
            w = rng.normal_f32(s, n, std)
        else:
            raise ValueError(f"unknown init {cfg.init!r}")
        ws.append(w.reshape(fan_in, fan_out))
    return ws


def activation(name: str):
    import jax.nn

    return {
        "sine": jnp.sin,
        "sigmoid": jax.nn.sigmoid,
        "relu": jax.nn.relu,
        "lrelu": lambda x: jax.nn.leaky_relu(x, 0.01),
        "elu": jax.nn.elu,
        "linear": lambda x: x,
    }[name]


def generator_ref(cfg: GenCfg, ws: list[jnp.ndarray], alpha: jnp.ndarray,
                  beta: jnp.ndarray, freq=None) -> jnp.ndarray:
    """Pure-jnp oracle. alpha: [n, k], beta: [n] → [n, d].

    ``freq`` may be a traced scalar (the Table-6 frequency-sweep executable
    takes it as a runtime input so one HLO covers the whole sweep).
    """
    act = activation(cfg.act)
    f = jnp.float32(cfg.freq) if freq is None else freq
    u = act(f * (alpha @ ws[0]))
    for w in ws[1:-1]:
        h = act(u @ w)
        u = h + u if cfg.residual else h
    v = act(u @ ws[-1])
    if cfg.normalize:
        v = v / (jnp.linalg.norm(v, axis=-1, keepdims=True) + 1e-8)
    return v * beta[:, None]
